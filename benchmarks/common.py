"""Shared benchmark helpers: graph suite, timing, CSV output.

This container is a single CPU core — wall-times here measure the *JAX
engines on CPU* (the sequential numpy references are the paper's baseline
role).  The TPU performance story lives in the dry-run roofline
(EXPERIMENTS.md §Roofline/§Perf); these benchmarks reproduce the paper's
*relative* claims: push-count ratios, parameter trends, work scaling.
"""
from __future__ import annotations

import time

import numpy as np
import jax

from repro.graphs import make_graph

# Stand-ins for the paper's graph suite (Table 2), CPU-sized.
GRAPH_SUITE = {
    "sbm-planted": dict(family="sbm", k=8, size=100, p_in=0.15, p_out=0.002),
    "randLocal-50k": dict(family="randLocal", n=50_000, degree=5),
    "3D-grid-20": dict(family="3D-grid", side=20),
    "rmat-12": dict(family="rmat", scale=12, edge_factor=8),
}

_CACHE = {}


def get_graph(name: str):
    if name not in _CACHE:
        kw = dict(GRAPH_SUITE[name])
        fam = kw.pop("family")
        _CACHE[name] = make_graph(fam, **kw)
    return _CACHE[name]


def timeit(fn, *args, repeats: int = 3, prime: bool = True, **kw):
    """Median wall time in µs (jit warm-up excluded by a priming call).

    ``prime=False`` skips the warm-up call — the measurement then includes
    compile time, which is what the CI smoke gate wants (run once, cheaply).
    """
    if prime:
        out = fn(*args, **kw)
        if jax.tree.leaves(out):
            jax.block_until_ready(jax.tree.leaves(out))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        leaves = jax.tree.leaves(out)
        if leaves:
            jax.block_until_ready(leaves)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6, out


# Rows emitted since the last drain — benchmarks/run.py drains this per
# suite to build the BENCH_<suite>.json artifact, so every suite's perf
# trajectory accumulates in CI even when its run() returns nothing.
_ROWS = []


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}", flush=True)
    _ROWS.append(dict(name=name, us_per_call=float(us), derived=derived))


def drain_rows():
    """Rows emitted since the previous drain (and reset the buffer)."""
    global _ROWS
    out, _ROWS = _ROWS, []
    return out
