"""The op-dispatch layer (core/ops.py): backend parity + driver bit-identity.

Two contracts (docs/architecture.md §Op-dispatch layer):

  1. *Op parity* — for every op, the ``pallas`` backend (interpret mode on
     CPU) returns **bit-identical** results to the ``xla`` reference and to
     the structure-free oracles in ``kernels/ref.py``, across dtypes,
     duplicate-heavy index patterns, and empty/overflow inputs.  (The one
     exception is ``diffusion_spmv``, which reassociates the banded row
     reduction — allclose, not bit-equal; and f32 ``prefix_sum``, whose
     blocked scan reassociates — the drivers only scan integers.)
  2. *Driver bit-identity* — every driver produces bit-identical outputs
     under ``backend="xla"`` and ``backend="pallas"``, single-seed and
     batched, dense and sparse.

Property tests need hypothesis (requirements-dev.txt); the fixed-case and
driver tests run regardless.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import ops
from repro.core import (pr_nibble, pr_nibble_sparse, hk_pr, evolving_sets,
                        sweep_cut, batched_pr_nibble,
                        batched_pr_nibble_sparse, batched_cluster,
                        batched_cluster_sparse)
from repro.kernels import ref
from repro.graphs import rand_local

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

GRAPH = rand_local(400, degree=5, seed=3)
CAPS = dict(cap_f=1 << 8, cap_e=1 << 12)


def bitwise_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype != b.dtype or a.shape != b.shape:
        return False
    return np.array_equal(np.atleast_1d(a).view(np.uint8),
                          np.atleast_1d(b).view(np.uint8))


# ---------------------------------------------------------------- registry

def test_registry_and_resolve():
    assert set(ops.backends()) >= {"xla", "pallas"}
    assert ops.resolve("auto") in ("xla", "pallas")
    assert ops.resolve("xla") == "xla"
    with pytest.raises(ValueError):
        ops.resolve("cuda")
    with pytest.raises(ValueError):
        ops.register_backend("bogus", not_an_op=lambda: None)


def test_register_backend_partial_falls_back_to_xla():
    ops.register_backend("_test_partial", prefix_sum=lambda x: jnp.cumsum(x))
    try:
        x = jnp.arange(5, dtype=jnp.int32)
        out = ops.prefix_sum(x, backend="_test_partial")
        assert bitwise_equal(out, jnp.cumsum(x))
        # unspecified op fell back to the xla reference
        vec = jnp.zeros(4, jnp.float32)
        got = ops.scatter_add(vec, jnp.array([1, 1]), jnp.array([1.0, 2.0]),
                              backend="_test_partial")
        assert bitwise_equal(got, np.array([0, 3, 0, 0], np.float32))
    finally:
        ops._REGISTRY.pop("_test_partial")


# ------------------------------------------------------------- scatter_add

@pytest.mark.parametrize("dtype", [np.float32, np.int32])
@pytest.mark.parametrize("case", ["dense_dups", "one_hot_collision", "empty",
                                  "all_invalid", "chunk_spill"])
def test_scatter_add_backend_parity(dtype, case):
    rng = np.random.default_rng(hash((str(dtype), case)) % 2**32)
    n = 300
    if case == "empty":
        m = 0
    elif case == "chunk_spill":
        m = 2000                      # >256 hits per tile → spill path
    else:
        m = 700
    if case == "one_hot_collision":
        idx = np.zeros(m, np.int32)   # every update lands on one slot
    else:
        idx = rng.integers(0, n, m).astype(np.int32)
    if dtype is np.float32:
        vals = (rng.random(m) - 0.3).astype(np.float32)
        vec = rng.random(n).astype(np.float32)
    else:
        vals = rng.integers(-5, 6, m).astype(np.int32)
        vec = rng.integers(0, 50, n).astype(np.int32)
    valid = np.ones(m, bool) if case != "all_invalid" else np.zeros(m, bool)
    if case == "dense_dups":
        valid = rng.random(m) < 0.8
    args = (jnp.asarray(vec), jnp.asarray(idx), jnp.asarray(vals),
            jnp.asarray(valid))
    want = ref.scatter_add_ref(*args)
    got_x = ops.scatter_add(*args, backend="xla")
    got_p = ops.scatter_add(*args, backend="pallas")
    assert bitwise_equal(got_x, want)
    assert bitwise_equal(got_p, want), f"pallas != ref for {dtype}/{case}"


def test_scatter_add_under_vmap_parity():
    rng = np.random.default_rng(0)
    B, n, m = 3, 200, 400
    vec = jnp.asarray(rng.random((B, n)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, (B, m)).astype(np.int32))
    vals = jnp.asarray(rng.random((B, m)).astype(np.float32))
    valid = jnp.asarray(rng.random((B, m)) < 0.7)
    import jax
    fx = jax.vmap(lambda v, i, w, ok: ops.scatter_add(v, i, w, ok,
                                                      backend="xla"))
    fp = jax.vmap(lambda v, i, w, ok: ops.scatter_add(v, i, w, ok,
                                                      backend="pallas"))
    assert bitwise_equal(fx(vec, idx, vals, valid), fp(vec, idx, vals, valid))


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(-5, 60), min_size=0, max_size=120),
           st.integers(0, 2**31 - 1))
    def test_scatter_add_property(idx, seed):
        """Random (possibly out-of-range, duplicate-heavy) index patterns:
        all three implementations agree bitwise."""
        rng = np.random.default_rng(seed)
        n = 50
        m = len(idx)
        idx = np.asarray(idx, np.int32)
        vals = (rng.random(m).astype(np.float32) * 2 - 0.5)
        vec = rng.random(n).astype(np.float32)
        valid = (idx >= 0) & (idx < n) & (rng.random(m) < 0.9)
        args = (jnp.asarray(vec), jnp.asarray(np.clip(idx, 0, n)),
                jnp.asarray(vals), jnp.asarray(valid))
        want = ref.scatter_add_ref(*args)
        assert bitwise_equal(ops.scatter_add(*args, backend="xla"), want)
        assert bitwise_equal(ops.scatter_add(*args, backend="pallas"), want)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 40), min_size=0, max_size=150),
           st.integers(2, 64), st.integers(0, 2**31 - 1))
    def test_segment_merge_property(ids, cap, seed):
        """Duplicate-heavy merges at arbitrary capacity (incl. overflowing):
        xla, pallas, and the dense oracle agree bitwise on every leaf."""
        rng = np.random.default_rng(seed)
        n = 40
        ids = np.asarray(ids + [n] * 7, np.int32)   # sentinel tail
        vals = rng.random(ids.shape[0]).astype(np.float32)
        args = (jnp.asarray(ids), jnp.asarray(vals))
        want = ref.segment_merge_ref(*args, n, cap)
        got_x = ops.segment_merge(*args, n, cap, backend="xla")
        got_p = ops.segment_merge(*args, n, cap, backend="pallas")
        for w, gx, gp in zip(want, got_x, got_p):
            assert bitwise_equal(gx, w)
            assert bitwise_equal(gp, w)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 3000), st.integers(0, 2**31 - 1),
           st.sampled_from(["int32", "float32"]))
    def test_prefix_sum_property(size, seed, dtype):
        rng = np.random.default_rng(seed)
        if dtype == "int32":
            x = rng.integers(-100, 100, size).astype(np.int32)
        else:
            x = rng.random(size).astype(np.float32)
        got_x = ops.prefix_sum(jnp.asarray(x), backend="xla")
        got_p = ops.prefix_sum(jnp.asarray(x), backend="pallas")
        assert bitwise_equal(got_x, jnp.cumsum(jnp.asarray(x)))
        if dtype == "int32":
            assert bitwise_equal(got_p, got_x)   # int scans are exact
        else:
            np.testing.assert_allclose(np.asarray(got_p), np.asarray(got_x),
                                       rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------- segment_merge

def test_segment_merge_empty_and_overflow():
    n, cap = 30, 4
    ids = jnp.full((10,), n, jnp.int32)               # all sentinel
    vals = jnp.ones((10,), jnp.float32)
    for backend in ("xla", "pallas"):
        out_ids, out_vals, count = ops.segment_merge(ids, vals, n, cap,
                                                     backend=backend)
        assert int(count) == 0
        assert np.all(np.asarray(out_ids) == n)
        assert np.all(np.asarray(out_vals) == 0)
    # 8 distinct ids into cap=4: count reports the uncapped support
    ids = jnp.asarray(np.arange(8, dtype=np.int32))
    vals = jnp.asarray(np.ones(8, np.float32))
    a = ops.segment_merge(ids, vals, n, cap, backend="xla")
    b = ops.segment_merge(ids, vals, n, cap, backend="pallas")
    assert int(a[2]) == int(b[2]) == 8
    for x, y in zip(a, b):
        assert bitwise_equal(x, y)


def test_segment_merge_spans_kernel_blocks():
    """Runs crossing the kernel's BLK boundaries still fold in stream order
    (the carried-scalar stitch)."""
    from repro.kernels.segment_merge import BLK
    rng = np.random.default_rng(5)
    n = 10
    tot = 3 * BLK + 17                    # few ids → giant runs across blocks
    ids = np.sort(rng.integers(0, n, tot)).astype(np.int32)
    perm = rng.permutation(tot)           # op sorts internally
    vals = rng.random(tot).astype(np.float32)
    args = (jnp.asarray(ids[perm]), jnp.asarray(vals))
    a = ops.segment_merge(*args, n, 16, backend="xla")
    b = ops.segment_merge(*args, n, 16, backend="pallas")
    for x, y in zip(a, b):
        assert bitwise_equal(x, y)


# ----------------------------------------------------------- diffusion_spmv

def test_diffusion_spmv_backends_allclose():
    from repro.kernels import ops as kops
    nbr, wgt, es, ed, ew, n_pad, W = kops.pack_banded_ell(GRAPH, halo=2)
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.random(n_pad), jnp.float32)
    ya = ops.diffusion_spmv(nbr, wgt, es, ed, ew, p, halo=2, backend="xla")
    yb = ops.diffusion_spmv(nbr, wgt, es, ed, ew, p, halo=2, backend="pallas")
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-5,
                               atol=1e-6)


# -------------------------------------------------- driver bit-identity

def _assert_result_bitwise(a, b):
    for name, x in a._asdict().items():
        y = getattr(b, name)
        if isinstance(x, tuple):      # nested NamedTuple (SparseVec) / buckets
            if hasattr(x, "_asdict"):
                _assert_result_bitwise(x, y)
            else:
                assert x == y
        else:
            assert bitwise_equal(x, y), f"field {name} differs"


def test_pr_nibble_backend_bit_identity():
    a = pr_nibble(GRAPH, 11, eps=1e-5, alpha=0.05, **CAPS)
    b = pr_nibble(GRAPH, 11, eps=1e-5, alpha=0.05, backend="pallas", **CAPS)
    _assert_result_bitwise(a, b)


def test_pr_nibble_beta_backend_bit_identity():
    a = pr_nibble(GRAPH, 11, eps=1e-5, alpha=0.05, beta=0.5, **CAPS)
    b = pr_nibble(GRAPH, 11, eps=1e-5, alpha=0.05, beta=0.5,
                  backend="pallas", **CAPS)
    _assert_result_bitwise(a, b)


def test_pr_nibble_sparse_backend_bit_identity():
    a = pr_nibble_sparse(GRAPH, 11, eps=1e-5, alpha=0.05, cap_v=1 << 9, **CAPS)
    b = pr_nibble_sparse(GRAPH, 11, eps=1e-5, alpha=0.05, cap_v=1 << 9,
                         backend="pallas", **CAPS)
    _assert_result_bitwise(a, b)


def test_hk_pr_backend_bit_identity():
    a = hk_pr(GRAPH, 11, N=8, eps=1e-4, t=5.0, **CAPS)
    b = hk_pr(GRAPH, 11, N=8, eps=1e-4, t=5.0, backend="pallas", **CAPS)
    _assert_result_bitwise(a, b)


def test_evolving_sets_backend_bit_identity():
    import jax
    key = jax.random.PRNGKey(4)
    a = evolving_sets(GRAPH, 11, T=12, B=20000, phi=0.3, cap_s=1 << 8,
                      cap_e=1 << 12, key=key)
    b = evolving_sets(GRAPH, 11, T=12, B=20000, phi=0.3, cap_s=1 << 8,
                      cap_e=1 << 12, key=key, backend="pallas")
    _assert_result_bitwise(a, b)


def test_sweep_cut_backend_bit_identity():
    res = pr_nibble(GRAPH, 11, eps=1e-5, alpha=0.05, **CAPS)
    p = np.asarray(res.p)
    nz = np.flatnonzero(p > 0).astype(np.int32)
    cap_n = 1 << 9
    assert nz.size <= cap_n
    ids = np.full(cap_n, GRAPH.n, np.int32)
    ids[: nz.size] = nz
    vals = np.zeros(cap_n, np.float32)
    vals[: nz.size] = p[nz]
    a = sweep_cut(GRAPH, jnp.asarray(ids), jnp.asarray(vals),
                  jnp.asarray(nz.size), 1 << 12)
    b = sweep_cut(GRAPH, jnp.asarray(ids), jnp.asarray(vals),
                  jnp.asarray(nz.size), 1 << 12, backend="pallas")
    _assert_result_bitwise(a, b)


def test_batched_drivers_backend_bit_identity():
    seeds = np.array([3, 7, 11, 19], np.int32)
    a = batched_pr_nibble(GRAPH, seeds, 1e-5, 0.05, **CAPS)
    b = batched_pr_nibble(GRAPH, seeds, 1e-5, 0.05, backend="pallas", **CAPS)
    for name in ("p", "r", "iterations", "pushes", "overflow"):
        assert bitwise_equal(getattr(a, name), getattr(b, name)), name

    sa = batched_pr_nibble_sparse(GRAPH, seeds, 1e-5, 0.05, cap_v=1 << 9,
                                  **CAPS)
    sb = batched_pr_nibble_sparse(GRAPH, seeds, 1e-5, 0.05, cap_v=1 << 9,
                                  backend="pallas", **CAPS)
    for name in ("p_ids", "p_vals", "p_count", "r_ids", "r_vals", "r_count",
                 "iterations", "pushes", "overflow"):
        assert bitwise_equal(getattr(sa, name), getattr(sb, name)), name

    ca = batched_cluster(GRAPH, seeds, 1e-5, 0.05, cap_n=1 << 8,
                         sweep_cap_e=1 << 12, **CAPS)
    cb = batched_cluster(GRAPH, seeds, 1e-5, 0.05, cap_n=1 << 8,
                         sweep_cap_e=1 << 12, backend="pallas", **CAPS)
    for name in ("conductance", "best_conductance", "best_size",
                 "best_volume", "support", "pushes", "iterations",
                 "overflow"):
        assert bitwise_equal(getattr(ca, name), getattr(cb, name)), name

    fa = batched_cluster_sparse(GRAPH, seeds, 1e-5, 0.05, cap_v=1 << 9,
                                sweep_cap_e=1 << 12, **CAPS)
    fb = batched_cluster_sparse(GRAPH, seeds, 1e-5, 0.05, cap_v=1 << 9,
                                sweep_cap_e=1 << 12, backend="pallas", **CAPS)
    for name in ("conductance", "best_conductance", "best_size",
                 "best_volume", "support", "pushes", "iterations",
                 "overflow"):
        assert bitwise_equal(getattr(fa, name), getattr(fb, name)), name


def test_engine_ops_backend_identity_and_pinning():
    from repro.serve import ClusterRequest, LocalClusterEngine
    eng_caps = dict(cap_f=1 << 8, cap_e=1 << 12, cap_n=1 << 8,
                    sweep_cap_e=1 << 12)
    reqs = [ClusterRequest(seed=s, eps=1e-5, alpha=0.05)
            for s in (3, 7, 11, 19)]
    ra = LocalClusterEngine(GRAPH, batch_slots=4, ops_backend="xla",
                            **eng_caps).run(reqs)
    rb = LocalClusterEngine(GRAPH, batch_slots=4, ops_backend="pallas",
                            **eng_caps).run(reqs)
    for a, b in zip(ra, rb):
        assert a.conductance == b.conductance
        assert a.size == b.size
        assert np.array_equal(a.cluster, b.cluster)
        assert (a.ops_backend, b.ops_backend) == ("xla", "pallas")
    # per-request pins coexist in one engine (separate pools, same results)
    eng = LocalClusterEngine(GRAPH, batch_slots=4, **eng_caps)
    mixed = eng.run([ClusterRequest(seed=3, eps=1e-5, alpha=0.05,
                                    ops_backend="pallas"),
                     ClusterRequest(seed=3, eps=1e-5, alpha=0.05,
                                    ops_backend="xla")])
    assert mixed[0].conductance == mixed[1].conductance
    assert {m.ops_backend for m in mixed} == {"pallas", "xla"}
