"""Tracer correctness: concurrency-safe ring buffer, purity (guarantee #8:
tracing never changes answers), full-lifecycle span trees, deadline-miss
postmortems, and the disabled-tracer near-zero-overhead contract."""
import json
import threading
import time

import numpy as np
import pytest

from repro.serve import (AsyncClusterEngine, ClusterRequest,
                         LocalClusterEngine, MetricsRegistry, Tracer)
from repro.serve.tracing import RequestTrace, annotate

ENGINE_CAPS = dict(cap_f=1 << 11, cap_e=1 << 15, cap_n=1 << 10,
                   sweep_cap_e=1 << 15)


def _requests(graph, n, seed=0, **kw):
    rng = np.random.default_rng(seed)
    seeds = rng.choice(np.flatnonzero(np.asarray(graph.deg) > 0), size=n)
    return [ClusterRequest(seed=int(s), alpha=0.05, eps=1e-4, **kw)
            for s in seeds]


def _assert_same_results(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.conductance == rb.conductance
        assert ra.size == rb.size and ra.volume == rb.volume
        assert ra.support == rb.support and ra.pushes == rb.pushes
        assert ra.iterations == rb.iterations and ra.bucket == rb.bucket
        assert np.array_equal(ra.cluster, rb.cluster)


# ------------------------------------------------------------------- purity

def test_engine_traced_bit_identical_to_untraced(sbm_graph):
    """Guarantee #8 at the engine layer: same stream, one flight-recorded."""
    reqs = _requests(sbm_graph, 10)
    traced = LocalClusterEngine(sbm_graph, batch_slots=4, tracer=Tracer(),
                                **ENGINE_CAPS).run(reqs)
    plain = LocalClusterEngine(sbm_graph, batch_slots=4,
                               **ENGINE_CAPS).run(reqs)
    _assert_same_results(traced, plain)


def test_scheduler_traced_bit_identical_and_full_lifecycle(sbm_graph):
    """Guarantee #8 through AsyncClusterEngine, driven deterministically
    (single-threaded tick(), no deadlines), plus the span-tree shape: every
    request's phases tile its root span."""
    reqs = _requests(sbm_graph, 8)
    tracer = Tracer()
    sched = AsyncClusterEngine(sbm_graph, batch_slots=4, tracer=tracer,
                               **ENGINE_CAPS)
    futs = [sched.submit(r) for r in reqs]
    while sched.inflight():
        sched.tick()
    traced = [f.result() for f in futs]
    plain = LocalClusterEngine(sbm_graph, batch_slots=4,
                               **ENGINE_CAPS).run(reqs)
    _assert_same_results(traced, plain)
    for fut in futs:
        rt = fut.trace
        assert rt.status == "resolved"
        # contiguous phases → coverage ~100% of the root span by
        # construction (the ≥95% artifact gate allows clock jitter)
        assert rt.coverage() >= 0.95
        for phase in ("queued", "pool_queue", "resident", "sweep",
                      "deliver"):
            assert phase in rt.phase_ms, phase
        tree = tracer.request_tree(rt.rid)
        assert tree["rid"] == rt.rid and len(tree["tree"]) == 1
        root = tree["tree"][0]
        assert root["name"] == "request"
        assert {c["name"] for c in root["children"]} >= {
            "queued", "pool_queue", "resident", "sweep", "deliver"}


# -------------------------------------------------------------- concurrency

def test_concurrent_emission_never_corrupts_ring():
    """Hammer one small-capacity tracer from many threads: the ring stays
    bounded, counts stay consistent, and every finished span is well-formed."""
    tracer = Tracer(capacity=256)
    n_threads, per_thread = 8, 300
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        barrier.wait()
        for i in range(per_thread):
            rt = tracer.request(tid=tid)
            rt.phase("queued")
            rt.event("injected", i=i)
            rt.phase("deliver")
            rt.finish("resolved")
            with tracer.span("tick", cat="pool", pool=f"t{tid}") as sid:
                with tracer.scope(parent=sid):
                    annotate("ladder_dispatch", hop=0)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tracer.spans()
    assert len(spans) <= 256 + len(tracer._open)
    assert tracer.dropped > 0          # capacity was genuinely exercised
    sids = [s.sid for s in spans]
    assert len(sids) == len(set(sids))  # no span recorded twice
    for s in spans:
        assert s.t1 is None or s.t1 >= s.t0
    # export stays structurally valid after the stampede
    json.dumps(tracer.chrome_trace())


# -------------------------------------------------------------- postmortems

def test_deadline_miss_dumps_postmortem(sbm_graph):
    tm = MetricsRegistry()
    tracer = Tracer()
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, telemetry=tm,
                               tracer=tracer, **ENGINE_CAPS)
    futs = [sched.submit(r, deadline_ms=0.001)
            for r in _requests(sbm_graph, 4)]
    while sched.inflight():
        sched.tick()
    missed = [f for f in futs if f.result().deadline_missed]
    assert missed, "instant deadlines must miss"
    snap = tm.snapshot()
    assert snap["schema"].startswith("repro.serve.metrics/")
    pms = snap["postmortems"]
    assert len(pms) == len(missed)
    for pm in pms:
        assert pm["tree"]["tree"], "postmortem carries the span tree"
        assert "phases_ms" in pm and pm["deadline_ms"] == 0.001
    json.dumps(snap)                  # snapshot stays JSON-able


def test_postmortems_bounded():
    tm = MetricsRegistry(max_postmortems=3)
    for i in range(10):
        tm.add_postmortem(dict(ticket=i))
    kept = tm.postmortems()
    assert [p["ticket"] for p in kept] == [7, 8, 9]


# ----------------------------------------------------------------- overhead

def test_disabled_tracer_is_near_zero_overhead(sbm_graph):
    """The ambient annotate() hook with no active scope must cost one
    attribute lookup — generous wall bound so CI can't flake."""
    t0 = time.perf_counter()
    for _ in range(100_000):
        annotate("ladder_dispatch", hop=0)
    assert time.perf_counter() - t0 < 0.5
    # engine without a tracer records nothing and allocates no traces
    eng = LocalClusterEngine(sbm_graph, batch_slots=2, **ENGINE_CAPS)
    eng.run(_requests(sbm_graph, 2))
    assert eng._rt == {}


# -------------------------------------------------------------------- export

def test_chrome_trace_shape():
    tracer = Tracer()
    rt = tracer.request(seed=1)
    rt.phase("queued")
    rt.event("injected", lane=0)
    rt.finish("resolved")
    with tracer.span("tick", cat="pool", pool="p"):
        pass
    events = tracer.chrome_trace()
    assert all(set(e) >= {"name", "cat", "pid", "tid", "ts", "ph"}
               for e in events)
    phs = {e["ph"] for e in events}
    assert phs == {"X", "i"}
    # request spans share the request's tid; pool spans sit on tid 0
    req_tids = {e["tid"] for e in events if e["args"].get("rid") == rt.rid}
    assert req_tids == {rt.rid + 1}
    assert {e["tid"] for e in events if e["name"] == "tick"} == {0}
    durs = [e["dur"] for e in events if e["ph"] == "X"]
    assert all(d >= 0 for d in durs)
    json.dumps(events)


def test_ladder_annotations_reach_active_scope(sbm_graph):
    """The core drivers' ladder_dispatch events land under a tick span when
    a scope is active — threaded up from repro.core.batched with no direct
    core→serve import."""
    from repro.core.batched import batched_pr_nibble
    tracer = Tracer()
    seeds = _requests(sbm_graph, 2)
    with tracer.span("tick", cat="pool") as sid:
        with tracer.scope(parent=sid):
            batched_pr_nibble(sbm_graph, [r.seed for r in seeds],
                              alpha=0.05, eps=1e-4,
                              cap_f=1 << 11, cap_e=1 << 15)
    ann = [s for s in tracer.spans() if s.name == "ladder_dispatch"]
    assert ann, "ladder dispatches must annotate the active scope"
    for s in ann:
        assert s.parent == sid
        assert "bucket" in s.attrs and "lanes" in s.attrs
        assert "pushes" in s.attrs
