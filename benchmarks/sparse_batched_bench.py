"""Batched sparse backend benchmark: pushes/sec and peak live values vs dense.

The sparse backend's claim (ISSUE 2 acceptance) is *memory-bounded many-seed
serving*: a dense batched lane persists 2·n f32 state values (p, r) however
small the cluster, while a sparse lane persists 2·cap_v values + 2·cap_v ids
— bounded by the lane's frontier/value capacity K, independent of n.  This
bench runs the same seed batch through both paths and reports:

  * pushes/sec for each path (identical push counts — the work is the same,
    only the state representation differs),
  * peak live diffusion values per lane (dense: 2n; sparse: 2·K of the
    largest dispatched bucket), and their ratio.

It *asserts* the memory-bound claim — every lane's final support fits its
K, and the sparse per-lane live values are what the capacity accounting
(:func:`repro.core.batched_sparse.sparse_lane_footprint`) predicts — and
that both paths computed the same diffusion (densified sparse p == dense p),
so the reported rates compare equal work.  Any violation raises, which
``benchmarks/run.py`` turns into a nonzero exit.
"""
import numpy as np

from repro.core import (batched_pr_nibble, batched_pr_nibble_sparse,
                        sparse_lane_footprint, sparse_rows_to_dense)
from .common import get_graph, emit, timeit


def run(smoke: bool = False):
    name = "sbm-planted" if smoke else "randLocal-50k"
    B = 8 if smoke else 32
    eps, alpha = 1e-6, 0.01
    dense_caps = (dict(cap_f=1 << 10, cap_e=1 << 14) if smoke
                  else dict(cap_f=1 << 12, cap_e=1 << 16))
    sparse_caps = dict(dense_caps, cap_v=1 << 10 if smoke else 1 << 12)
    prime = not smoke
    g = get_graph(name)
    rng = np.random.default_rng(0)
    seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                       size=B).astype(np.int32)

    us_d, out_d = timeit(batched_pr_nibble, g, seeds, eps, alpha,
                         repeats=1, prime=prime, **dense_caps)
    us_s, out_s = timeit(batched_pr_nibble_sparse, g, seeds, eps, alpha,
                         repeats=1, prime=prime, **sparse_caps)

    pushes = int(out_d.pushes.sum())
    assert int(out_s.pushes.sum()) == pushes, \
        "sparse backend did different work than dense"
    np.testing.assert_allclose(
        sparse_rows_to_dense(out_s.p_ids, out_s.p_vals, out_s.p_count, g.n),
        out_d.p, atol=1e-6, err_msg="sparse and dense diffusions disagree")

    # peak live diffusion values per lane: dense persists p,r = 2n floats;
    # sparse persists 2·K floats (+ 2·K ids) of the largest bucket it used
    cap_v_max = max(b[3] for b in out_s.buckets)
    assert (out_s.p_count <= cap_v_max).all() and \
           (out_s.r_count <= cap_v_max).all(), \
        "lane support exceeded its value capacity K"
    live_sparse = 2 * cap_v_max
    assert live_sparse == sparse_lane_footprint(
        1, 1, cap_v_max)["state"] // 2, "footprint accounting drifted"
    live_dense = 2 * g.n

    emit(f"sparse_batched/{name}/dense_B={B}", us_d,
         f"pushes_per_sec={pushes / max(us_d * 1e-6, 1e-12):.0f};"
         f"live_vals_per_lane={live_dense}")
    emit(f"sparse_batched/{name}/sparse_B={B}", us_s,
         f"pushes_per_sec={pushes / max(us_s * 1e-6, 1e-12):.0f};"
         f"live_vals_per_lane={live_sparse};K={cap_v_max};"
         f"dense_over_sparse_mem={live_dense / live_sparse:.1f}x;"
         f"buckets={len(out_s.buckets)};asserts=ok")


if __name__ == "__main__":
    run()
