"""GraphHandle — the single graph-carrying contract across every layer.

Before this abstraction each layer hard-coded *which* physical graph
representation it consumed: the drivers and the serving engine demanded a
resident :class:`~repro.graphs.csr.CSRGraph`, while the distributed engine
took a bare :class:`~repro.graphs.partition.PartitionedCSR` plus a mesh —
which made the sharded path a dead end off the serving path.  ``GraphHandle``
is the tagged union over both:

  * **local**       — a device-resident ``CSRGraph`` (the single-chip case);
  * **partitioned** — a ``PartitionedCSR`` (row slabs stacked on a leading
    device axis) together with the mesh/axis it is sharded over, optionally
    *alongside* the local CSR it was partitioned from.

Callers ask the handle questions (``n``, ``m``, ``degrees()``,
``is_sharded``, ``num_shards``) instead of reaching into a representation,
and materialize the representation they need on demand:

  * :meth:`GraphHandle.local` returns the resident CSR — reconstructing it
    host-side from the partition slabs (and caching it) if the handle was
    built sharded-first.  Sweep cuts and the dense/sparse lane pools go
    through here.
  * :meth:`GraphHandle.partitioned` returns the ``PartitionedCSR`` —
    partitioning the local CSR over the handle's mesh axis on first use (and
    caching).  The distributed drivers (`repro.core.distributed`,
    `repro.core.batched_dist`) go through here.

Every public driver accepts either a raw ``CSRGraph`` or a ``GraphHandle``
(coerced via :func:`as_handle` / :func:`as_local_csr`), so single-chip call
sites are unchanged while sharded graphs flow through the same signatures.

``n`` is always the *true* (unpadded) vertex count: the partition pads the
last shard with isolated sentinel vertices (see
`repro.graphs.partition.PartitionedCSR` padding contract) and the handle is
where that padding is made invisible — distributed state vectors of length
``n_pad`` are sliced back to ``n`` before they reach any consumer.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np
import jax.numpy as jnp

from .csr import CSRGraph
from .partition import PartitionedCSR, partition_rows

__all__ = ["GraphHandle", "as_handle", "as_local_csr"]


class GraphHandle:
    """Tagged union over local / partitioned graph representations.

    Build with :meth:`from_csr`, :meth:`from_partitioned`, or :meth:`shard`;
    or coerce anything graph-like with :func:`as_handle`.
    """

    def __init__(self, *, csr: Optional[CSRGraph] = None,
                 pg: Optional[PartitionedCSR] = None,
                 mesh: Any = None, axis: str = "data"):
        if csr is None and pg is None:
            raise ValueError("GraphHandle needs a CSRGraph or a PartitionedCSR")
        self._csr = csr
        self._pg = pg
        self.mesh = mesh
        self.axis = axis
        # Monotonic content version: the serving layer's seed→result cache
        # (repro.serve.result_cache) keys on it, so bumping it on any edge
        # mutation invalidates every cached community at once.  The handle
        # owns it because the handle is the graph-identity contract — both
        # representations (csr, pg) describe one logical graph at one
        # version.
        self.version = 0

    # -- constructors --------------------------------------------------------

    @classmethod
    def from_csr(cls, csr: CSRGraph) -> "GraphHandle":
        """Local (single-chip) handle."""
        return cls(csr=csr)

    @classmethod
    def from_partitioned(cls, pg: PartitionedCSR, mesh: Any = None,
                         axis: str = "data",
                         csr: Optional[CSRGraph] = None) -> "GraphHandle":
        """Sharded handle; ``csr`` optionally carries the source graph so
        :meth:`local` is free instead of a host-side reconstruction."""
        return cls(csr=csr, pg=pg, mesh=mesh, axis=axis)

    @classmethod
    def shard(cls, csr: CSRGraph, mesh: Any,
              axis: str = "data") -> "GraphHandle":
        """Partition a local CSR over ``mesh``'s ``axis`` (kept alongside)."""
        pg = partition_rows(csr, int(mesh.shape[axis]))
        return cls(csr=csr, pg=pg, mesh=mesh, axis=axis)

    # -- tag / shape questions ----------------------------------------------

    @property
    def kind(self) -> str:
        return "partitioned" if self._pg is not None else "local"

    @property
    def is_sharded(self) -> bool:
        return self._pg is not None

    @property
    def has_local(self) -> bool:
        """True when a resident CSR is already materialized."""
        return self._csr is not None

    @property
    def num_shards(self) -> int:
        return self._pg.num_shards if self._pg is not None else 1

    @property
    def n(self) -> int:
        """True (unpadded) vertex count."""
        if self._csr is not None:
            return self._csr.n
        return self._pg.n_true

    @property
    def n_pad(self) -> int:
        """Padded vertex count of the sharded layout (== n when local)."""
        return self._pg.n if self._pg is not None else self._csr.n

    @property
    def m(self) -> int:
        return (self._csr or self._pg).m

    @property
    def total_volume(self) -> int:
        return 2 * self.m

    def degrees(self) -> np.ndarray:
        """Host int32[n] degree vector — available for either tag without
        materializing a CSR (the partition slabs already carry degrees)."""
        if self._csr is not None:
            return np.asarray(self._csr.deg)
        return np.asarray(self._pg.deg).reshape(-1)[: self.n]

    def bump_version(self) -> int:
        """Advance the content version (call after mutating the graph the
        handle wraps).  Serving-layer result caches key on the version, so
        stale communities can never be served after a bump."""
        self.version += 1
        return self.version

    def require_mesh(self):
        if self.mesh is None:
            raise ValueError(
                "this GraphHandle is sharded but carries no mesh; build it "
                "with GraphHandle.shard(csr, mesh) or from_partitioned(pg, "
                "mesh=...) to use the distributed drivers")
        return self.mesh

    # -- representation materializers ---------------------------------------

    def local(self) -> CSRGraph:
        """The resident CSR, reconstructed from the partition slabs (host
        side, cached) when the handle was built sharded-first."""
        if self._csr is None:
            self._csr = _gather_csr(self._pg)
        return self._csr

    def partitioned(self, num_shards: Optional[int] = None) -> PartitionedCSR:
        """The row-sharded slabs, partitioning the local CSR on first use.
        ``num_shards`` defaults to the mesh axis size."""
        if self._pg is None:
            if num_shards is None:
                num_shards = int(self.require_mesh().shape[self.axis])
            self._pg = partition_rows(self._csr, num_shards)
        elif num_shards is not None and num_shards != self._pg.num_shards:
            raise ValueError(
                f"handle is partitioned over {self._pg.num_shards} shards, "
                f"requested {num_shards}")
        return self._pg

    def __repr__(self) -> str:
        tag = (f"partitioned[{self.num_shards}x{self._pg.rows_per}]"
               if self.is_sharded else "local")
        return f"GraphHandle({tag}, n={self.n}, m={self.m})"


def _gather_csr(pg: PartitionedCSR) -> CSRGraph:
    """Rebuild the global CSR from per-shard slabs (columns are global ids
    already; padded sentinel rows are dropped)."""
    deg = np.asarray(pg.deg).reshape(-1)[: pg.n_true].astype(np.int32)
    indptr = np.zeros(pg.n_true + 1, dtype=np.int32)
    np.cumsum(deg, out=indptr[1:])
    slabs = []
    host_indptr = np.asarray(pg.indptr)
    host_indices = np.asarray(pg.indices)
    for d in range(pg.num_shards):
        slabs.append(host_indices[d, : int(host_indptr[d, -1])])
    indices = (np.concatenate(slabs) if slabs
               else np.zeros(0, np.int32)).astype(np.int32)
    return CSRGraph(indptr=jnp.asarray(indptr), indices=jnp.asarray(indices),
                    deg=jnp.asarray(deg), n=int(pg.n_true), m=int(pg.m))


def as_handle(graph, mesh: Any = None, axis: str = "data") -> GraphHandle:
    """Coerce anything graph-like into a :class:`GraphHandle`.

    ``CSRGraph`` → local handle (sharded over ``mesh`` when one is given);
    ``PartitionedCSR`` → partitioned handle; an existing handle passes
    through unchanged — unless a ``mesh`` is given and the handle has none,
    in which case a *new* handle is returned (sharing the cached
    representations, never mutating the caller's object).  A ``mesh`` that
    conflicts with the handle's own is an error, not a silent override.
    """
    if isinstance(graph, GraphHandle):
        if mesh is None:
            return graph
        if graph.mesh is None:
            return GraphHandle(csr=graph._csr, pg=graph._pg,
                               mesh=mesh, axis=axis)
        if graph.mesh != mesh or graph.axis != axis:
            raise ValueError(
                f"mesh/axis conflict: handle carries {graph.mesh} over "
                f"{graph.axis!r}, caller passed {mesh} over {axis!r} — "
                f"build a fresh handle for a different topology")
        return graph
    if isinstance(graph, PartitionedCSR):
        return GraphHandle.from_partitioned(graph, mesh=mesh, axis=axis)
    if isinstance(graph, CSRGraph):
        if mesh is not None:
            return GraphHandle.shard(graph, mesh, axis)
        return GraphHandle.from_csr(graph)
    raise TypeError(f"expected CSRGraph | PartitionedCSR | GraphHandle, "
                    f"got {type(graph).__name__}")


def as_local_csr(graph) -> CSRGraph:
    """The resident-CSR view of anything graph-like (see :func:`as_handle`)."""
    if isinstance(graph, CSRGraph):
        return graph
    return as_handle(graph).local()
