"""Encoder-decoder assembly (whisper-medium).

The audio frontend (two strided convs over the mel spectrogram) is a STUB per
the assignment: ``input_specs`` supplies precomputed frame embeddings
[B, enc_seq, D].  The encoder is a bidirectional attention stack; the decoder
is the shared lm.py stack with per-block cross-attention (with_cross=True).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from .layers import rmsnorm, rmsnorm_init
from .lm import (block_init, block_apply, lm_init, lm_loss, lm_prefill,
                 lm_decode_step)

__all__ = ["encdec_init", "encode", "encdec_loss", "encdec_prefill",
           "encdec_decode_step"]


def encdec_init(key, cfg: ModelConfig):
    k_enc, k_dec = jax.random.split(key)
    enc_layers = [block_init(jax.random.fold_in(k_enc, i), cfg, "attn_bidir")
                  for i in range(cfg.n_enc_layers)]
    return {
        "enc_scan": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
        "enc_norm": rmsnorm_init(cfg.d_model),
        "dec": lm_init(k_dec, cfg, with_cross=True),
    }


def encode(params, frames, cfg: ModelConfig, remat: bool = True):
    """frames: [B, enc_seq, D] (stubbed frontend) → encoder states."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = frames.astype(jnp.dtype(cfg.compute_dtype))

    def body(x, layer_params):
        y, _ = block_apply(layer_params, x, positions, "attn_bidir", cfg)
        return y, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_scan"])
    return rmsnorm(params["enc_norm"], x)


def encdec_loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
                remat: bool = True):
    enc_out = encode(params, batch["frames"], cfg, remat)
    return lm_loss(params["dec"], batch, cfg, remat=remat, enc_out=enc_out)


def encdec_prefill(params, frames, tokens, cfg: ModelConfig, max_seq: int,
                   remat: bool = True):
    enc_out = encode(params, frames, cfg, remat)
    cache, logits = lm_prefill(params["dec"], tokens, cfg, max_seq,
                               remat=remat, enc_out=enc_out)
    return cache, logits, enc_out


def encdec_decode_step(params, token, cache, enc_out, cfg: ModelConfig):
    return lm_decode_step(params["dec"], token, cache, cfg, enc_out=enc_out)
