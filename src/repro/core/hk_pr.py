"""Parallel deterministic heat-kernel PageRank (paper §4.4, Figure 5).

Kloster–Gleich hk-relax: approximate h = e⁻ᵗ Σₖ tᵏ/k! · Pᵏ s via its degree-N
Taylor polynomial, pushing residual mass level by level.  The paper's insight:
all queue entries with the same Taylor index j can be processed in parallel
(they only write level j+1), so the rounds of the parallel algorithm are the
Taylor levels and the output is *identical* to the sequential algorithm.

ψ coefficients: ψ_N = 1, ψ_k = 1 + t·ψ_{k+1}/(k+1)  (O(N) instead of the
naive O(N²); still matches Theorem 4's bound).  Threshold (Fig 5 /
Kloster–Gleich):  r[v] ≥ eᵗ·ε·d(v) / (2N·ψ_{j+1}(t)).

Work O(N² + N·eᵗ/ε), depth O(N·t·log(1/ε))  (Theorem 4).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .frontier import (Frontier, expand, pack_unique, singleton,
                       scatter_add_dense, one_hot_f32)

__all__ = ["HKPRResult", "HKPRState", "hk_pr", "hk_pr_fixedcap", "psis",
           "hk_pr_init", "hk_pr_round", "hk_pr_alive"]


def psis(N: int, t: float) -> np.ndarray:
    psi = np.ones(N + 1, dtype=np.float64)
    for k in range(N - 1, -1, -1):
        psi[k] = 1.0 + t * psi[k + 1] / (k + 1)
    return psi


class HKPRResult(NamedTuple):
    p: jnp.ndarray
    iterations: jnp.ndarray
    pushes: jnp.ndarray
    edge_work: jnp.ndarray
    overflow: jnp.ndarray


class HKPRState(NamedTuple):
    """Loop carry of one hk-relax run — exposed so batched/streaming drivers
    (core/batched.py, serve/cluster_engine.py) can step the same rounds."""
    p: jnp.ndarray
    r: jnp.ndarray
    frontier: Frontier
    j: jnp.ndarray
    pushes: jnp.ndarray
    edge_work: jnp.ndarray
    done: jnp.ndarray
    overflow: jnp.ndarray


def hk_pr_init(x, n: int, cap_f: int) -> HKPRState:
    r0 = one_hot_f32(x, n)
    return HKPRState(p=jnp.zeros((n,), jnp.float32), r=r0,
                     frontier=singleton(x, n, cap_f),
                     j=jnp.asarray(0, jnp.int32),
                     pushes=jnp.asarray(0, jnp.int32),
                     edge_work=jnp.asarray(0, jnp.int32),
                     done=jnp.asarray(False),
                     overflow=jnp.asarray(False))


def hk_pr_alive(s: HKPRState) -> jnp.ndarray:
    return (~s.done) & (~s.overflow) & (s.frontier.count > 0)


def hk_pr_round(graph: CSRGraph, s: HKPRState, N: int, eps, t: float,
                cap_e: int, backend: str = "xla") -> HKPRState:
    """One Taylor level (the while-loop body of Figure 5).  ``N`` and ``t``
    are trace-time constants: the ψ table is precomputed host-side in
    float64.  ``backend`` routes the scatters/scans (repro.core.ops)."""
    n = graph.n
    deg = graph.deg
    psi_table = jnp.asarray(psis(N, float(t)), jnp.float32)
    scale = jnp.exp(jnp.asarray(t, jnp.float32))

    f = s.frontier
    fvalid = f.valid()
    fids = jnp.where(fvalid, f.ids, n)
    safe = jnp.minimum(fids, n - 1)
    rf = jnp.where(fvalid, s.r[safe], 0.0)
    dv = jnp.maximum(deg[safe], 1)

    # VERTEXMAP (UpdateSelf): p[v] += r[v]
    p_new = scatter_add_dense(s.p, fids, rf, fvalid, backend=backend)

    eb = expand(graph, f, cap_e, backend=backend)
    last = s.j + 1 >= N

    # last round (UpdateNghLast): p[w] += r[v]/d(v), then stop
    contrib_last = rf[eb.slot] / dv[eb.slot]
    p_last = scatter_add_dense(p_new, eb.dst, contrib_last, eb.valid,
                               backend=backend)

    # normal round (UpdateNgh): r'[w] += t·r[v]/((j+1)·d(v)); fresh r'
    contrib = (t * rf[eb.slot]) / ((s.j + 1.0) * dv[eb.slot])
    r_next = jnp.zeros_like(s.r)
    r_next = scatter_add_dense(r_next, eb.dst, contrib, eb.valid,
                               backend=backend)

    # frontier for level j+1: r'[v] ≥ eᵗ ε d(v) / (2N ψ_{j+1})
    thresh_coef = scale * eps / (2.0 * N * psi_table[jnp.minimum(s.j + 1, N)])
    cands = eb.dst
    csafe = jnp.minimum(cands, n - 1)
    keep = eb.valid & (deg[csafe] > 0) & \
        (r_next[csafe] >= deg[csafe] * thresh_coef)
    nf = pack_unique(cands, keep, n, s.frontier.cap, backend=backend)

    return HKPRState(
        p=jnp.where(last, p_last, p_new),
        r=jnp.where(last, s.r, r_next),
        frontier=nf,
        j=s.j + 1,
        pushes=s.pushes + f.count,
        edge_work=s.edge_work + eb.total,
        done=last,
        overflow=s.overflow | eb.overflow | (nf.overflow & ~last),
    )


@functools.partial(jax.jit, static_argnums=(2, 4, 5, 6),
                   static_argnames=("N", "t", "cap_f", "cap_e", "backend"))
def hk_pr_fixedcap(graph: CSRGraph, x, N: int, eps, t: float,
                   cap_f: int, cap_e: int, *,
                   backend: str = "xla") -> HKPRResult:
    def cond(s: HKPRState):
        return hk_pr_alive(s)

    def body(s: HKPRState) -> HKPRState:
        return hk_pr_round(graph, s, N, eps, t, cap_e, backend)

    s = jax.lax.while_loop(cond, body, hk_pr_init(x, graph.n, cap_f))
    return HKPRResult(p=s.p, iterations=s.j, pushes=s.pushes,
                      edge_work=s.edge_work, overflow=s.overflow)


def hk_pr(graph: CSRGraph, x, N: int = 20, eps: float = 1e-7, t: float = 10.0,
          cap_f: int = 1 << 12, cap_e: int = 1 << 16,
          max_cap_e: int = 1 << 26, backend: str = "xla") -> HKPRResult:
    """Bucketed driver: retry with doubled capacities on overflow."""
    while True:
        out = hk_pr_fixedcap(graph, x, N, eps, t, cap_f, cap_e,
                             backend=backend)
        if not bool(out.overflow) or cap_e >= max_cap_e:
            return out
        cap_f = min(cap_f * 2, graph.n + 1)
        cap_e = cap_e * 2
