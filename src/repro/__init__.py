"""repro — Parallel Local Graph Clustering (Shun et al. 2016) as a
production JAX/TPU framework, plus the multi-arch LM substrate it is
benchmarked against.  See DESIGN.md."""

__version__ = "0.1.0"
