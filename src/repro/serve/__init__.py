from .engine import ServeConfig, generate, batched_serve

__all__ = ["ServeConfig", "generate", "batched_serve"]
