"""Diffusion engines vs their sequential references (paper §4.2–4.5)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (nibble, pr_nibble, pr_nibble_sparse, hk_pr,
                        rand_hk_pr, evolving_sets, seq, sweep_cut_dense)
from repro.core.sparsevec import sv_lookup
from conftest import dense_from_dict


# ---------------------------------------------------------------- Nibble ---

def test_nibble_matches_sequential(sbm_graph):
    res = nibble(sbm_graph, 5, eps=1e-7, T=15)
    ref = seq.seq_nibble(sbm_graph, 5, 1e-7, 15)
    p_ref = dense_from_dict(ref["p"], sbm_graph.n)
    np.testing.assert_allclose(np.asarray(res.p), p_ref, atol=1e-6)
    assert int(res.iterations) == ref["iterations"]


def test_nibble_mass_bounded(sbm_graph):
    """Truncation only removes mass: ‖p‖₁ ≤ 1 and > 0."""
    res = nibble(sbm_graph, 3, eps=1e-6, T=10)
    total = float(jnp.sum(res.p))
    assert 0.0 < total <= 1.0 + 1e-5


def test_nibble_work_bound(sbm_graph):
    """Theorem 2: per-iteration work O(1/ε) — edge work bounded."""
    eps = 1e-5
    res = nibble(sbm_graph, 5, eps=eps, T=20)
    per_iter = float(res.edge_work) / max(int(res.iterations), 1)
    assert per_iter <= 4.0 / eps  # generous constant


# ------------------------------------------------------------- PR-Nibble ---

def test_pr_nibble_mass_conservation(sbm_graph):
    res = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    total = float(jnp.sum(res.p) + jnp.sum(res.r))
    assert total == pytest.approx(1.0, abs=1e-4)


def test_pr_nibble_agrees_with_sequential(sbm_graph):
    res = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    ref = seq.seq_pr_nibble(sbm_graph, 5, 1e-6, 0.05, optimized=True)
    p_ref = dense_from_dict(ref["p"], sbm_graph.n)
    p_par = np.asarray(res.p, np.float64)
    corr = np.corrcoef(p_par, p_ref)[0, 1]
    assert corr > 0.9999


def test_pr_nibble_parallel_push_overhead(sbm_graph):
    """Table 1: parallel pushes exceed sequential but within a small factor
    (paper: ≤1.6× on its graphs; we allow 2.5× on tiny synthetic ones)."""
    res = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    ref = seq.seq_pr_nibble(sbm_graph, 5, 1e-6, 0.05, optimized=True)
    ratio = int(res.pushes) / max(ref["pushes"], 1)
    assert 1.0 <= ratio < 2.5
    # iterations ≪ pushes (abundant parallelism)
    assert int(res.iterations) < int(res.pushes) / 10


def test_pr_nibble_work_bound(sbm_graph):
    """Theorem 3: total edge work ≤ O(1/(αε)) regardless of rounds."""
    eps, alpha = 1e-5, 0.05
    res = pr_nibble(sbm_graph, 5, eps=eps, alpha=alpha)
    assert float(res.edge_work) <= 4.0 / (alpha * eps)


def test_pr_nibble_rules_same_cluster(sbm_graph):
    """Fig 2: optimized rule finds the same-conductance cluster."""
    a = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05, optimized=True)
    b = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05, optimized=False)
    sa = sweep_cut_dense(sbm_graph, a.p, 1 << 10, 1 << 16)
    sb = sweep_cut_dense(sbm_graph, b.p, 1 << 10, 1 << 16)
    assert float(sa.best_conductance) == pytest.approx(
        float(sb.best_conductance), rel=0.1)
    # optimized does no more work
    assert int(a.pushes) <= int(b.pushes)


def test_pr_nibble_sparse_equals_dense(sbm_graph):
    d = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    s = pr_nibble_sparse(sbm_graph, 5, eps=1e-6, alpha=0.05)
    ids = np.asarray(s.p.ids)[: int(s.p.count)]
    vals = np.asarray(s.p.vals)[: int(s.p.count)]
    p_sparse = np.zeros(sbm_graph.n, np.float32)
    p_sparse[ids] = vals
    np.testing.assert_allclose(p_sparse, np.asarray(d.p), atol=1e-6)
    assert int(s.pushes) == int(d.pushes)


def test_pr_nibble_beta_variant(sbm_graph):
    """β<1 (top-β by r/d per round, paper §4.3 variant) terminates, conserves
    mass, and produces the same solution up to the ε tolerance.  (It often
    *reduces* total pushes — prioritizing high-residual vertices mimics the
    sequential order — the work/parallelism trade-off the paper describes.)"""
    full = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05, beta=1.0)
    part = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05, beta=0.5)
    assert not bool(part.overflow)
    mass = float(np.sum(np.asarray(part.p)) + np.sum(np.asarray(part.r)))
    assert mass == pytest.approx(1.0, abs=1e-4)
    assert int(part.pushes) <= int(full.pushes) * 1.2
    corr = np.corrcoef(np.asarray(full.p), np.asarray(part.p))[0, 1]
    assert corr > 0.999


# ----------------------------------------------------------------- HK-PR ---

def test_hk_pr_identical_to_sequential(sbm_graph):
    """Claim C3: the parallel algorithm applies the same updates."""
    res = hk_pr(sbm_graph, 5, N=10, eps=1e-5, t=5.0)
    ref = seq.seq_hk_pr(sbm_graph, 5, 10, 1e-5, 5.0)
    p_ref = dense_from_dict(ref["p"], sbm_graph.n)
    p_par = np.asarray(res.p, np.float64)
    np.testing.assert_allclose(p_par, p_ref, rtol=1e-3, atol=1e-5 * p_ref.max())


def test_hk_pr_converges_to_taylor_oracle(sbm_graph):
    """ε→0 limit equals the untruncated degree-N Taylor recurrence."""
    res = hk_pr(sbm_graph, 5, N=8, eps=1e-9, t=3.0)
    ref = seq.seq_hk_pr(sbm_graph, 5, 8, 0.0, 3.0, truncate=False)
    p_ref = dense_from_dict(ref["p"], sbm_graph.n)
    p_par = np.asarray(res.p, np.float64)
    assert np.corrcoef(p_par, p_ref)[0, 1] > 0.9999


# ------------------------------------------------------------ rand-HK-PR ---

def test_rand_hk_histogram_is_exact(sbm_graph):
    """The sort+prefix-sum histogram equals numpy bincount of destinations."""
    res = rand_hk_pr(sbm_graph, 5, 4096, 10, 5.0, jax.random.PRNGKey(0))
    dests = np.asarray(res.dests)
    counts = np.bincount(dests, minlength=sbm_graph.n)
    ids = np.asarray(res.ids)[: int(res.nnz)]
    vals = np.asarray(res.vals)[: int(res.nnz)]
    np.testing.assert_allclose(vals * 4096, counts[ids])
    assert float(res.vals.sum()) == pytest.approx(1.0, abs=1e-6)


def test_rand_hk_concentrates_in_block(sbm_graph):
    res = rand_hk_pr(sbm_graph, 5, 8192, 8, 3.0, jax.random.PRNGKey(1))
    ids = np.asarray(res.ids)[: int(res.nnz)]
    vals = np.asarray(res.vals)[: int(res.nnz)]
    mass_in_block = vals[ids < 100].sum()
    assert mass_in_block > 0.6
    # and it matches the sequential walker's distribution closely
    ref = seq.seq_rand_hk_pr(sbm_graph, 5, 4096, 8, 3.0, seed=2)
    p_ref = dense_from_dict(ref["p"], sbm_graph.n)
    mass_ref = p_ref[:100].sum()
    assert abs(mass_in_block - mass_ref) < 0.1


# ---------------------------------------------------------- Evolving sets ---

def test_evolving_sets_recovers_planted(sbm_graph):
    res = evolving_sets(sbm_graph, 5, 40, 10**7, 0.15,
                        key=jax.random.PRNGKey(0))
    members = np.asarray(res.ids)[: int(res.count)]
    assert np.mean(members < 100) > 0.8
    assert float(res.conductance) < 0.2


def test_pr_nibble_seed_set(sbm_graph):
    """Paper footnote 3: multi-vertex seed sets — bigger frontiers, same
    contract; a seed set inside one block still recovers that block."""
    from repro.core.sweep import sweep_cut_dense
    seeds = jnp.asarray([5, 17, 42, 63], jnp.int32)
    res = pr_nibble(sbm_graph, (seeds, 4), eps=1e-6, alpha=0.05)
    mass = float(jnp.sum(res.p) + jnp.sum(res.r))
    assert mass == pytest.approx(1.0, abs=1e-4)
    sw = sweep_cut_dense(sbm_graph, res.p, 1 << 11, 1 << 17)
    members = np.asarray(sw.cluster())[: int(sw.best_size)]
    assert np.mean(members < 100) > 0.85
    # multi-seed first round pushes ≥ 1 vertex per seed
    single = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    assert int(res.iterations) <= int(single.iterations) + 5
