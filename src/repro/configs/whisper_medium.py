"""whisper-medium — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].
24L (dec) + 24L (enc) d_model=1024 16H d_ff=4096 vocab=51865; input_specs
provide precomputed frame embeddings [B, 1500, d_model]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    layer_pattern=("attn",),
    enc_dec=True, n_enc_layers=24, enc_seq=1500,
    modality="audio",
    source="arXiv:2212.04356 (unverified); frontend stubbed",
)
