"""Serving-latency benchmark: deadline scheduler under a Poisson stream.

The serving claim is different from the throughput claims of
`batched_bench.py`: here requests *arrive over time* (Poisson process), each
with a latency budget, and the metric is the request-latency distribution —
p50/p95/p99 — plus the deadline-miss rate, per lane backend (dense vs
sparse).  The `AsyncClusterEngine` runs in its background drive thread while
this process plays an open-loop arrival schedule at it, the standard
serving-benchmark shape.

Emits the usual `name,us_per_call,derived` CSV rows (us = p50 latency) and
returns a JSON-able dict that `benchmarks/run.py` writes to
``BENCH_serve.json`` — the artifact CI uploads so the serving-latency
trajectory accumulates across PRs.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serve import AsyncClusterEngine, ClusterRequest
from .common import get_graph, emit


def _percentiles(lat_ms):
    lat = np.sort(np.asarray(lat_ms, np.float64))
    pick = lambda q: float(lat[min(len(lat) - 1,
                                   int(round(q / 100 * (len(lat) - 1))))])
    return dict(p50_ms=pick(50), p95_ms=pick(95), p99_ms=pick(99))


def _run_lane(graph, backend: str, n_requests: int, mean_gap_s: float,
              deadline_ms: float, batch_slots: int, caps: dict,
              seed: int = 0) -> dict:
    """Play one Poisson-arrival stream at a fresh scheduler; returns the
    latency/miss summary for the BENCH_serve.json artifact."""
    rng = np.random.default_rng(seed)
    seeds = rng.choice(np.flatnonzero(np.asarray(graph.deg) > 0),
                       size=n_requests).astype(np.int32)
    gaps = rng.exponential(mean_gap_s, size=n_requests)
    sched = AsyncClusterEngine(graph, batch_slots=batch_slots,
                               max_queue=4 * n_requests, backend=backend,
                               **caps)
    futs = []
    with sched:
        # warm the compile caches (all requests share one pool family), so
        # the timed stream measures serving behavior, not jit time
        sched.submit(ClusterRequest(seed=int(seeds[0]), alpha=0.05,
                                    eps=1e-4)).result(timeout=300.0)
        t0 = time.perf_counter()
        for s, gap in zip(seeds, gaps):
            time.sleep(float(gap))      # open-loop: arrivals don't wait
            futs.append(sched.submit(
                ClusterRequest(seed=int(s),
                               alpha=float(rng.choice([0.05, 0.01])),
                               eps=float(rng.choice([1e-4, 1e-5]))),
                deadline_ms=deadline_ms))
        results = [f.result(timeout=300.0) for f in futs]
        wall_s = time.perf_counter() - t0
    lat_ms = [f.latency_ms for f in futs]
    missed = sum(r.deadline_missed for r in results)
    out = _percentiles(lat_ms)
    out.update(
        deadline_miss_rate=missed / n_requests,
        n_requests=n_requests,
        deadline_ms=deadline_ms,
        mean_gap_ms=mean_gap_s * 1e3,
        wall_s=wall_s,
        throughput_rps=n_requests / wall_s,
        backend=backend,
    )
    return out


def run(smoke: bool = False) -> dict:
    name = "sbm-planted" if smoke else "randLocal-50k"
    graph = get_graph(name)
    n_requests = 16 if smoke else 64
    mean_gap_s = 0.002 if smoke else 0.005
    # the budget is deliberately tight enough that the slower lane misses it
    # under the burst (the miss path must exercise in CI), loose enough that
    # warm dense ticks hit — both outcomes are *reported*, never asserted
    deadline_ms = 1000.0 if smoke else 250.0
    caps = (dict(cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                 sweep_cap_e=1 << 14) if smoke else {})
    artifact = dict(graph=name, smoke=smoke, lanes={})
    for backend in ("dense", "sparse"):
        lane = _run_lane(graph, backend, n_requests, mean_gap_s, deadline_ms,
                         batch_slots=4 if smoke else 8, caps=caps)
        artifact["lanes"][backend] = lane
        emit(f"serve/{name}/{backend}_poisson_B={n_requests}",
             lane["p50_ms"] * 1e3,
             f"p95_ms={lane['p95_ms']:.1f};p99_ms={lane['p99_ms']:.1f};"
             f"miss_rate={lane['deadline_miss_rate']:.3f};"
             f"rps={lane['throughput_rps']:.1f}")
    return artifact


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2))
