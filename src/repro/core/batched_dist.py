"""Sharded batched PR-Nibble — vmap-over-seeds × shard_map-over-``data``.

The batched engine (core/batched.py) amortizes B seed queries into one XLA
dispatch, but assumes the whole CSR and B dense state vectors fit one chip.
This module lifts the same batched rounds onto a vertex-partitioned graph
(`repro.graphs.partition.PartitionedCSR` under a ``data`` mesh axis): every
device holds its row slab plus the [B, rows_per] slice of every lane's
``p``/``r``, each round expands all B local frontiers at once, and one
bucketed all_to_all per round routes all B lanes' cross-shard contributions
together — message volume ∝ total boundary mass of the batch, the
distributed analogue of the paper's work-locality (and of Spielman–Teng's
boundary-proportional locality argument).

Bit-identity (docs/algorithms.md guarantee #7): each lane's trajectory is
bit-identical to the single-chip dense driver because every float fold
happens in the same order —

  * the single-chip frontier is *sorted by vertex id* (``pack_unique``
    sorts); under range partitioning, concatenating the per-device local
    frontiers in device order reproduces exactly that order;
  * per-device expansion walks frontier slots in order and each row's edges
    in CSR order, so the global contribution stream is ordered
    (owner-device of the *source*, slot, edge) — the single-chip order;
  * routing sorts contributions by owner with a *stable* argsort and the
    all_to_all concatenates received buckets in source-device order, so the
    scatter-add at each destination vertex folds its contributions in the
    single-chip stream order.

Termination and overflow keep the batched contract: lanes are masked like
XLA's vmapped while-loop (``select(alive, new, old)`` per lane), and
overflowed lanes are repacked and retried one power-of-two bucket up by the
shared :func:`repro.core.batched._bucketed_retry` ladder — now also
laddering the per-owner exchange-bucket capacity ``cap_x`` (clamped at
``cap_e``).  Overflow is exact: local frontier (``cap_f``), local edge
workspace (``cap_e``), or any per-owner bucket (``cap_x``) exceeding
capacity flags the lane.  Sharing ``_bucketed_retry`` also means dist
ladder dispatches annotate an active trace scope
(:func:`repro.serve.tracing.annotate`) with the paper-native work measures
— including the ``exchanged`` cross-shard contribution volume — and the
engine's dist pools surface the same counter per lane in their harvest
``lane_obs`` events.

The module also exposes the step-wise lane kernels
(:func:`dist_lane_kernels`: init / inject / step) that
``LocalClusterEngine``'s ``backend="dist"`` pools drive — the same round
body, advanced a bounded number of rounds per scheduler tick.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.graphs.handle import as_handle
from .batched import BatchedClusterResult, _bucketed_retry, _prep_batch, \
    _CapLadder, batched_sweep_cut
from .distributed import (_local_expand, local_frontier_pack, owner_buckets,
                          push_shares)
from .frontier import scatter_add_dense, scatter_set_dense
from .pr_nibble import MAX_ITERS

__all__ = [
    "BatchedDistDiffusionResult", "DistLaneState",
    "batched_dist_pr_nibble", "batched_cluster_dist", "dist_lane_kernels",
]

class BatchedDistDiffusionResult(NamedTuple):
    p: np.ndarray           # f32[B, n]   (true n — sentinel padding sliced)
    r: np.ndarray           # f32[B, n]
    iterations: np.ndarray  # int32[B]
    pushes: np.ndarray      # int32[B]
    edge_work: np.ndarray   # int32[B]
    exchanged: np.ndarray   # int32[B] — cross-shard contribution slots routed
    overflow: np.ndarray    # bool[B] — True only if max_cap_e was exhausted
    buckets: Tuple[tuple, ...]   # (batch, cap_f, cap_e, cap_x) dispatched


class DistLaneState(NamedTuple):
    """Sharded per-lane state the engine pools carry between ticks.

    ``p``/``r`` are [B, n_pad] sharded over the mesh axis on dim 1; the
    scalars are replicated [B].
    """
    p: jnp.ndarray
    r: jnp.ndarray
    t: jnp.ndarray            # int32[B]
    pushes: jnp.ndarray       # int32[B]
    edge_work: jnp.ndarray    # int32[B]
    exchanged: jnp.ndarray    # int32[B]
    front: jnp.ndarray        # int32[B] — global frontier count
    overflow: jnp.ndarray     # bool[B]


def _lane_alive(front, overflow, t, max_iters: int = MAX_ITERS):
    return (front > 0) & (~overflow) & (t < max_iters)


# -------------------------------------------------- per-device round (B lanes)

def _make_round(axis: str, D: int, rows_per: int, cap_f: int, cap_e: int,
                cap_x: int, optimized: bool, backend: str):
    """Round body that runs INSIDE shard_map: advances all B lanes one
    synchronous push round against this device's slab, with one batched
    all_to_all for the whole lane batch."""

    def round_all(indptr, indices, deg, me, base, p, r, eps, alpha):
        def lane_local(p1, r1, e1, a1):
            # local frontier / push rule / owner bucketing are the shared
            # fold-order-critical helpers of repro.core.distributed — one
            # definition serves both distributed engines
            ids, cnt = local_frontier_pack(r1, deg, e1, rows_per, cap_f,
                                           backend)
            f_ovf = cnt > cap_f
            f_cnt = jnp.minimum(cnt, cap_f)
            f_valid = jnp.arange(cap_f, dtype=jnp.int32) < f_cnt
            safe = jnp.minimum(ids, rows_per - 1)
            rf = jnp.where(f_valid, r1[safe], 0.0)
            dv = jnp.maximum(deg[safe], 1)
            p_gain, r_self, share = push_shares(rf, dv, a1, optimized)
            p_new = scatter_add_dense(p1, ids, p_gain, f_valid,
                                      backend=backend)
            r_new = scatter_set_dense(r1, ids, r_self, f_valid)
            slot, dst, evalid, etot = _local_expand(
                indptr, indices, deg, ids, f_valid, cap_e, rows_per, backend)
            contrib = jnp.where(evalid, share[slot], 0.0)
            owner, send_dst, send_val, x_ovf = owner_buckets(
                dst, contrib, evalid, D, rows_per, cap_x, cap_e)
            exch = jnp.sum((owner != me) & evalid).astype(jnp.int32)
            ovf = f_ovf | x_ovf | (etot > cap_e)
            return p_new, r_new, send_dst, send_val, f_cnt, etot, exch, ovf

        (p_new, r_new, send_dst, send_val, f_cnt, etot, exch, ovf) = \
            jax.vmap(lane_local)(p, r, eps, alpha)
        # one collective for the whole lane batch: [B, D, cap_x] along owners
        recv_dst = jax.lax.all_to_all(send_dst, axis, 1, 1, tiled=True)
        recv_val = jax.lax.all_to_all(send_val, axis, 1, 1, tiled=True)
        B = p.shape[0]
        loc = recv_dst.reshape(B, -1) - base
        ok = (loc >= 0) & (loc < rows_per)
        r_new = jax.vmap(
            lambda rr, ll, vv, kk: scatter_add_dense(rr, ll, vv, kk,
                                                     backend=backend)
        )(r_new, loc, recv_val.reshape(B, -1), ok)
        above_next = jax.vmap(
            lambda rr, e1: jnp.sum((rr >= deg * e1) & (deg > 0))
        )(r_new, eps).astype(jnp.int32)
        gfront = jax.lax.psum(above_next, axis)
        gpush = jax.lax.psum(f_cnt, axis)
        getot = jax.lax.psum(etot, axis)
        gexch = jax.lax.psum(exch, axis)
        lane_ovf = jax.lax.psum(ovf.astype(jnp.int32), axis) > 0
        return p_new, r_new, gfront, gpush, getot, gexch, lane_ovf

    return round_all


def _masked_advance(c: DistLaneState, alive, rnd) -> DistLaneState:
    """Fold one round's outputs into the carry, per-lane masked exactly like
    the vmapped while-loop batching rule (finished lanes keep their state)."""
    p_new, r_new, gfront, gpush, getot, gexch, lane_ovf = rnd
    sel = jnp.where(alive[:, None], p_new, c.p), \
        jnp.where(alive[:, None], r_new, c.r)
    return DistLaneState(
        p=sel[0], r=sel[1],
        t=jnp.where(alive, c.t + 1, c.t),
        pushes=jnp.where(alive, c.pushes + gpush, c.pushes),
        edge_work=jnp.where(alive, c.edge_work + getot, c.edge_work),
        exchanged=jnp.where(alive, c.exchanged + gexch, c.exchanged),
        front=jnp.where(alive, gfront, c.front),
        overflow=jnp.where(alive, c.overflow | lane_ovf, c.overflow))


def _init_lanes(seeds, base, rows_per: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[B, rows_per] zero ``p`` and seed-one-hot ``r`` local slices."""
    B = seeds.shape[0]
    mine = (seeds >= base) & (seeds < base + rows_per)
    loc = jnp.clip(seeds - base, 0, rows_per - 1)
    r0 = jax.vmap(
        lambda i, ok: scatter_add_dense(jnp.zeros((rows_per,), jnp.float32),
                                        i[None], jnp.float32(1.0)[None],
                                        ok[None])
    )(loc, mine)
    return jnp.zeros((B, rows_per), jnp.float32), r0


# ------------------------------------------------------------- jitted kernels

@functools.lru_cache(maxsize=None)
def _fixedcap_kernel(mesh, axis: str, rows_per: int, cap_f: int, cap_e: int,
                     cap_x: int, optimized: bool, max_iters: int,
                     backend: str):
    """jit(shard_map) of the full batched run at one capacity bucket."""
    D = int(mesh.shape[axis])
    round_all = _make_round(axis, D, rows_per, cap_f, cap_e, cap_x,
                            optimized, backend)

    def engine(indptr, indices, deg, seeds, eps, alpha):
        indptr, indices, deg = indptr[0], indices[0], deg[0]
        me = jax.lax.axis_index(axis)
        base = me * rows_per
        B = seeds.shape[0]
        p0, r0 = _init_lanes(seeds, base, rows_per)
        z = jnp.zeros((B,), jnp.int32)
        c0 = DistLaneState(p=p0, r=r0, t=z, pushes=z, edge_work=z,
                           exchanged=z, front=jnp.ones((B,), jnp.int32),
                           overflow=jnp.zeros((B,), bool))

        def cond(c):
            return jnp.any(_lane_alive(c.front, c.overflow, c.t, max_iters))

        def body(c):
            alive = _lane_alive(c.front, c.overflow, c.t, max_iters)
            rnd = round_all(indptr, indices, deg, me, base,
                            c.p, c.r, eps, alpha)
            return _masked_advance(c, alive, rnd)

        c = jax.lax.while_loop(cond, body, c0)
        return c

    return jax.jit(shard_map(
        engine, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(), P(), P()),
        out_specs=DistLaneState(p=P(None, axis), r=P(None, axis), t=P(),
                                pushes=P(), edge_work=P(), exchanged=P(),
                                front=P(), overflow=P())))


@functools.lru_cache(maxsize=None)
def dist_lane_kernels(mesh, axis: str, rows_per: int, cap_f: int, cap_e: int,
                      cap_x: int, optimized: bool, backend: str):
    """(init, inject, step) kernels for the engine's ``dist`` lane pools.

    * ``init(seeds[B]) -> DistLaneState`` — fresh sharded state, one lane per
      seed (pools start them inactive and overwrite via inject).
    * ``inject(state, lane, seed) -> DistLaneState`` — reset one lane to a
      fresh seed; ``lane``/``seed`` are traced, so refill never recompiles.
    * ``step(indptr, indices, deg, state, eps, alpha, active, rounds) ->
      DistLaneState`` — advance every active lane up to ``rounds`` rounds
      (``rounds`` static).  Identical round body to the fixedcap kernel, so
      a dist lane's trajectory is bit-identical to the single-chip driver's
      regardless of tick boundaries.
    """
    D = int(mesh.shape[axis])
    state_specs = DistLaneState(p=P(None, axis), r=P(None, axis), t=P(),
                                pushes=P(), edge_work=P(), exchanged=P(),
                                front=P(), overflow=P())
    round_all = _make_round(axis, D, rows_per, cap_f, cap_e, cap_x,
                            optimized, backend)

    def init(seeds):
        me = jax.lax.axis_index(axis)
        base = me * rows_per
        B = seeds.shape[0]
        p0, r0 = _init_lanes(seeds, base, rows_per)
        z = jnp.zeros((B,), jnp.int32)
        return DistLaneState(p=p0, r=r0, t=z, pushes=z, edge_work=z,
                             exchanged=z, front=jnp.ones((B,), jnp.int32),
                             overflow=jnp.zeros((B,), bool))

    def inject(state, lane, seed):
        me = jax.lax.axis_index(axis)
        base = me * rows_per
        mine = (seed >= base) & (seed < base + rows_per)
        row_r = scatter_add_dense(jnp.zeros((rows_per,), jnp.float32),
                                  jnp.clip(seed - base, 0, rows_per - 1)[None],
                                  jnp.float32(1.0)[None], mine[None])
        z = jnp.asarray(0, jnp.int32)
        return DistLaneState(
            p=state.p.at[lane].set(0.0),
            r=state.r.at[lane].set(row_r),
            t=state.t.at[lane].set(z),
            pushes=state.pushes.at[lane].set(z),
            edge_work=state.edge_work.at[lane].set(z),
            exchanged=state.exchanged.at[lane].set(z),
            front=state.front.at[lane].set(jnp.asarray(1, jnp.int32)),
            overflow=state.overflow.at[lane].set(False))

    def step(indptr, indices, deg, state, eps, alpha, active, *, rounds):
        indptr, indices, deg = indptr[0], indices[0], deg[0]
        me = jax.lax.axis_index(axis)
        base = me * rows_per

        def cond(carry):
            c, k = carry
            alive = active & _lane_alive(c.front, c.overflow, c.t, MAX_ITERS)
            return (k < rounds) & jnp.any(alive)

        def body(carry):
            c, k = carry
            alive = active & _lane_alive(c.front, c.overflow, c.t, MAX_ITERS)
            rnd = round_all(indptr, indices, deg, me, base,
                            c.p, c.r, eps, alpha)
            return _masked_advance(c, alive, rnd), k + 1

        c, _ = jax.lax.while_loop(cond, body,
                                  (state, jnp.asarray(0, jnp.int32)))
        return c

    init_fn = jax.jit(shard_map(init, mesh=mesh, in_specs=(P(),),
                                out_specs=state_specs))
    inject_fn = jax.jit(shard_map(inject, mesh=mesh,
                                  in_specs=(state_specs, P(), P()),
                                  out_specs=state_specs))
    step_fns = {}

    def step_for(rounds: int):
        """One jitted step kernel per (static) rounds-per-tick value."""
        if rounds not in step_fns:
            step_fns[rounds] = jax.jit(shard_map(
                functools.partial(step, rounds=rounds), mesh=mesh,
                in_specs=(P(axis), P(axis), P(axis), state_specs,
                          P(), P(), P()),
                out_specs=state_specs))
        return step_fns[rounds]

    return init_fn, inject_fn, step_for


# ----------------------------------------------------------------- host driver

def batched_dist_pr_nibble(graph, seeds, eps=1e-7, alpha=0.01,
                           optimized: bool = True, cap_f: int = 1 << 12,
                           cap_e: int = 1 << 16, cap_x: int = 1 << 12,
                           max_cap_e: int = 1 << 26,
                           max_iters: int = MAX_ITERS, backend: str = "xla",
                           mesh: Any = None,
                           axis: str = "data") -> BatchedDistDiffusionResult:
    """Batched distributed driver with the per-seed bucketed retry ladder.

    ``graph`` is any graph-like (``CSRGraph`` + ``mesh``, ``PartitionedCSR``
    + ``mesh``, or a sharded ``GraphHandle``).  Per-seed outputs (``p``,
    ``r``, ``iterations``, ``pushes``, ``edge_work``) are bit-identical to
    :func:`repro.core.batched.batched_pr_nibble` on the gathered graph —
    including seeds that climb the ladder, because both paths converge to a
    non-overflowing bucket running the identical round trajectory.  ``cap_f``
    and ``cap_e`` are *per-shard* capacities here; ``cap_x`` is the
    per-owner exchange bucket (laddered alongside, clamped at ``cap_e``).
    """
    handle = as_handle(graph, mesh=mesh, axis=axis)
    mesh = handle.require_mesh()
    axis = handle.axis
    pg = handle.partitioned()
    seeds, B, eps, alpha = _prep_batch(seeds, eps, alpha)
    n = pg.n_true
    out = dict(p=np.zeros((B, n), np.float32), r=np.zeros((B, n), np.float32),
               iterations=np.zeros(B, np.int32), pushes=np.zeros(B, np.int32),
               edge_work=np.zeros(B, np.int32),
               exchanged=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    # clamp the *initial* caps like the ladder clamps its steps: a local
    # frontier can't exceed the shard's rows, a bucket can't exceed cap_e
    cap_f = min(cap_f, pg.rows_per + 1)
    cap_x = min(cap_x, cap_e)
    lad = _CapLadder(pg.rows_per, cap_f, cap_e, max_cap_e, cap_x=cap_x)

    def dispatch(sel):
        fn = _fixedcap_kernel(mesh, axis, pg.rows_per, lad.cap_f, lad.cap_e,
                              lad.cap_x, optimized, max_iters, backend)
        c = fn(pg.indptr, pg.indices, pg.deg, jnp.asarray(seeds[sel]),
               jnp.asarray(eps[sel]), jnp.asarray(alpha[sel]))
        fields = dict(p=np.asarray(c.p)[:, :n], r=np.asarray(c.r)[:, :n],
                      iterations=np.asarray(c.t), pushes=np.asarray(c.pushes),
                      edge_work=np.asarray(c.edge_work),
                      exchanged=np.asarray(c.exchanged),
                      overflow=np.asarray(c.overflow))
        return fields, (sel.size, lad.cap_f, lad.cap_e, lad.cap_x)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out,
                              ovf)
    return BatchedDistDiffusionResult(overflow=ovf, buckets=buckets, **out)


def batched_cluster_dist(graph, seeds, eps=1e-6, alpha=0.01,
                         optimized: bool = True, cap_f: int = 1 << 12,
                         cap_e: int = 1 << 16, cap_x: int = 1 << 12,
                         cap_n: int = 1 << 12, sweep_cap_e: int = 1 << 18,
                         max_cap_e: int = 1 << 26, backend: str = "xla",
                         mesh: Any = None,
                         axis: str = "data") -> BatchedClusterResult:
    """Distributed diffusion + per-lane sweep cut — the dist NCP inner loop.

    The diffusion runs sharded (:func:`batched_dist_pr_nibble`); the sweep
    runs on the handle's local CSR (gathered once and cached) over the
    bit-identical ``p`` rows, so curves equal the dense path's.  Sweep
    curves are reported on the ``min(cap_n, n)`` grid of the first bucket,
    like :func:`repro.core.batched.batched_cluster`.
    """
    handle = as_handle(graph, mesh=mesh, axis=axis)
    diff = batched_dist_pr_nibble(handle, seeds, eps, alpha, optimized,
                                  cap_f, cap_e, cap_x, max_cap_e,
                                  backend=backend)
    g = handle.local()
    n = g.n
    grid = min(cap_n, n)
    B = diff.p.shape[0]
    out = dict(conductance=np.full((B, grid), np.inf, np.float32),
               best_conductance=np.full(B, np.inf, np.float32),
               best_size=np.zeros(B, np.int32),
               best_volume=np.zeros(B, np.int32),
               support=np.zeros(B, np.int32))
    sweep_ovf = np.ones(B, bool)
    pending = np.arange(B)
    c_n, c_se = grid, sweep_cap_e
    p_dev = jnp.asarray(diff.p)
    while pending.size:
        sw = batched_sweep_cut(g, p_dev[pending], c_n, c_se, backend=backend)
        o = np.asarray(sw.overflow)
        exhausted = c_n >= n and c_se >= max_cap_e
        done = pending if exhausted else pending[~o]
        take = slice(None) if exhausted else ~o
        out["conductance"][done] = \
            np.asarray(sw.conductance)[take][:, :grid]
        out["best_conductance"][done] = np.asarray(sw.best_conductance)[take]
        out["best_size"][done] = np.asarray(sw.best_size)[take]
        out["best_volume"][done] = np.asarray(sw.best_volume)[take]
        out["support"][done] = np.asarray(sw.nnz)[take]
        sweep_ovf[done] = o[take]
        if exhausted:
            break
        pending = pending[o]
        c_n = min(c_n * 2, n)
        c_se = min(c_se * 2, max_cap_e)
    return BatchedClusterResult(
        pushes=diff.pushes, iterations=diff.iterations,
        overflow=diff.overflow | sweep_ovf, buckets=diff.buckets, **out)
