"""Serving-latency benchmark: deadline scheduler under a Poisson stream.

The serving claim is different from the throughput claims of
`batched_bench.py`: here requests *arrive over time* (Poisson process), each
with a latency budget, and the metric is the request-latency distribution —
p50/p95/p99 — plus the deadline-miss rate, per lane backend (dense vs
sparse).  The `AsyncClusterEngine` runs in its background drive thread while
this process plays an open-loop arrival schedule at it, the standard
serving-benchmark shape.

Emits the usual `name,us_per_call,derived` CSV rows (us = p50 latency) and
returns a JSON-able dict that `benchmarks/run.py` writes to
``BENCH_serve.json`` — the artifact CI uploads so the serving-latency
trajectory accumulates across PRs.

``--trace`` additionally flight-records every request through a
:class:`repro.serve.tracing.Tracer` and writes ``BENCH_trace.json``:
Chrome trace events (load in Perfetto), a per-request phase-attribution
table (queued / pool_queue / resident / sweep / deliver, with coverage =
how much of the measured wall latency the spans explain), the
deadline-miss postmortems from the telemetry snapshot, and a purity probe
asserting the traced stream is bit-identical to an untraced one.
"""
from __future__ import annotations

import time

import numpy as np

from repro.serve import (AsyncClusterEngine, ClusterRequest,
                         LocalClusterEngine, MetricsRegistry, Tracer)
from repro.serve.tracing import TRACE_SCHEMA
from .common import get_graph, emit


def _percentiles(lat_ms):
    lat = np.sort(np.asarray(lat_ms, np.float64))
    pick = lambda q: float(lat[min(len(lat) - 1,
                                   int(round(q / 100 * (len(lat) - 1))))])
    return dict(p50_ms=pick(50), p95_ms=pick(95), p99_ms=pick(99))


def _run_lane(graph, backend: str, n_requests: int, mean_gap_s: float,
              deadline_ms: float, batch_slots: int, caps: dict,
              seed: int = 0, tracer=None, telemetry=None) -> dict:
    """Play one Poisson-arrival stream at a fresh scheduler; returns the
    latency/miss summary for the BENCH_serve.json artifact.  With a
    ``tracer`` the summary also carries per-request phase attribution,
    Chrome trace events, and the telemetry postmortems."""
    rng = np.random.default_rng(seed)
    seeds = rng.choice(np.flatnonzero(np.asarray(graph.deg) > 0),
                       size=n_requests).astype(np.int32)
    gaps = rng.exponential(mean_gap_s, size=n_requests)
    sched = AsyncClusterEngine(graph, batch_slots=batch_slots,
                               max_queue=4 * n_requests, backend=backend,
                               tracer=tracer, telemetry=telemetry,
                               **caps)
    futs = []
    with sched:
        # warm the compile caches (all requests share one pool family), so
        # the timed stream measures serving behavior, not jit time
        sched.submit(ClusterRequest(seed=int(seeds[0]), alpha=0.05,
                                    eps=1e-4)).result(timeout=300.0)
        t0 = time.perf_counter()
        for s, gap in zip(seeds, gaps):
            time.sleep(float(gap))      # open-loop: arrivals don't wait
            futs.append(sched.submit(
                ClusterRequest(seed=int(s),
                               alpha=float(rng.choice([0.05, 0.01])),
                               eps=float(rng.choice([1e-4, 1e-5]))),
                deadline_ms=deadline_ms))
        results = [f.result(timeout=300.0) for f in futs]
        wall_s = time.perf_counter() - t0
    lat_ms = [f.latency_ms for f in futs]
    missed = sum(r.deadline_missed for r in results)
    out = _percentiles(lat_ms)
    out.update(
        deadline_miss_rate=missed / n_requests,
        n_requests=n_requests,
        deadline_ms=deadline_ms,
        mean_gap_ms=mean_gap_s * 1e3,
        wall_s=wall_s,
        throughput_rps=n_requests / wall_s,
        backend=backend,
    )
    if tracer is not None:
        recs = []
        for f, r in zip(futs, results):
            s = f.trace.summary()
            s["deadline_missed"] = bool(r.deadline_missed)
            # coverage against the *scheduler-measured* wall latency, the
            # number the artifact reports (the root span tracks it to µs)
            if f.latency_ms:
                s["coverage"] = min(1.0, sum(s["phases_ms"].values())
                                    / f.latency_ms)
            recs.append(s)
        out["requests"] = recs
        covs = [s["coverage"] for s in recs if s["coverage"] is not None]
        out["coverage_min"] = min(covs) if covs else None
        out["coverage_mean"] = (sum(covs) / len(covs)) if covs else None
        out["events"] = tracer.chrome_trace()
        out["spans_dropped"] = tracer.dropped
        out["postmortems"] = telemetry.postmortems()
    return out


def _purity_probe(graph, batch_slots: int, caps: dict, n: int = 8) -> dict:
    """Deterministic traced-vs-untraced comparison (guarantee #8): the same
    request list through two fresh engines, one flight-recorded, one not —
    every result field must agree bitwise.  Single-threaded and deadline-
    free so the comparison is exact, not timing-dependent."""
    rng = np.random.default_rng(7)
    seeds = rng.choice(np.flatnonzero(np.asarray(graph.deg) > 0), size=n)
    reqs = [ClusterRequest(seed=int(s), alpha=0.05, eps=1e-4) for s in seeds]
    traced = LocalClusterEngine(graph, batch_slots=batch_slots,
                                tracer=Tracer(), **caps).run(reqs)
    plain = LocalClusterEngine(graph, batch_slots=batch_slots,
                               **caps).run(reqs)
    identical = all(
        a.conductance == b.conductance and a.size == b.size
        and a.volume == b.volume and a.support == b.support
        and a.pushes == b.pushes and a.iterations == b.iterations
        and np.array_equal(a.cluster, b.cluster)
        for a, b in zip(traced, plain))
    return dict(n_requests=n, bit_identical=identical)


def run(smoke: bool = False, trace: bool = False) -> dict:
    name = "sbm-planted" if smoke else "randLocal-50k"
    graph = get_graph(name)
    n_requests = 16 if smoke else 64
    mean_gap_s = 0.002 if smoke else 0.005
    # the budget is deliberately tight enough that the slower lane misses it
    # under the burst (the miss path must exercise in CI), loose enough that
    # warm dense ticks hit — both outcomes are *reported*, never asserted
    deadline_ms = 1000.0 if smoke else 250.0
    batch_slots = 4 if smoke else 8
    caps = (dict(cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                 sweep_cap_e=1 << 14) if smoke else {})
    artifact = dict(graph=name, smoke=smoke, lanes={})
    traced_lanes = {}
    for backend in ("dense", "sparse"):
        tracer = Tracer(capacity=1 << 16) if trace else None
        telemetry = MetricsRegistry() if trace else None
        lane = _run_lane(graph, backend, n_requests, mean_gap_s, deadline_ms,
                         batch_slots=batch_slots, caps=caps,
                         tracer=tracer, telemetry=telemetry)
        if trace:
            # the trace payload goes to BENCH_trace.json, not BENCH_serve
            traced_lanes[backend] = {
                k: lane.pop(k) for k in ("requests", "events", "postmortems",
                                         "coverage_min", "coverage_mean",
                                         "spans_dropped")}
            traced_lanes[backend]["deadline_miss_rate"] = \
                lane["deadline_miss_rate"]
        artifact["lanes"][backend] = lane
        emit(f"serve/{name}/{backend}_poisson_B={n_requests}",
             lane["p50_ms"] * 1e3,
             f"p95_ms={lane['p95_ms']:.1f};p99_ms={lane['p99_ms']:.1f};"
             f"miss_rate={lane['deadline_miss_rate']:.3f};"
             f"rps={lane['throughput_rps']:.1f}")
    if trace:
        import json
        # one Perfetto-loadable event stream: lanes separated by pid
        events = []
        for pid, (backend, tl) in enumerate(traced_lanes.items()):
            for ev in tl.pop("events"):
                events.append(dict(ev, pid=pid))
        trace_artifact = dict(
            schema=TRACE_SCHEMA, suite="serve_trace", smoke=smoke,
            generated_unix=time.time(), graph=name,
            deadline_ms=deadline_ms,
            purity=_purity_probe(graph, batch_slots, caps),
            lanes=traced_lanes,
            traceEvents=events)
        with open("BENCH_trace.json", "w") as f:
            json.dump(trace_artifact, f, indent=2, sort_keys=True)
        print("wrote BENCH_trace.json", flush=True)
        artifact["trace_artifact"] = "BENCH_trace.json"
    return artifact


if __name__ == "__main__":
    import argparse
    import json
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="flight-record every request; write BENCH_trace.json")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke, trace=args.trace), indent=2))
