"""Pallas TPU kernels for the paper's compute hot-spots.

  ell_spmv      — banded ELL SpMV (saturated diffusion round), one-hot MXU gather
  scatter_accum — sort-bucketed scatter-add (fetchAdd → systolic contraction)
  prefix_scan   — two-phase blocked prefix sum (sweep-cut backbone)

``ops`` holds the jit'd layout wrappers, ``ref`` the pure-jnp oracles.
Kernels compile for TPU; on CPU they run under ``interpret=True``.
"""
from . import ops, ref
from .ell_spmv import band_spmv, ROW_BLOCK
from .scatter_accum import scatter_accum_tiles, TILE
from .prefix_scan import block_scan, BLOCK

__all__ = ["ops", "ref", "band_spmv", "ROW_BLOCK", "scatter_accum_tiles",
           "TILE", "block_scan", "BLOCK"]
