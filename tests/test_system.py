"""End-to-end behaviour: the paper's full pipeline on planted-cluster graphs,
the serving path, and the NCP driver."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (pr_nibble, nibble, hk_pr, rand_hk_pr, sweep_cut,
                        sweep_cut_dense, ncp)
from repro.graphs import sbm, make_graph
from repro.configs import smoke_config
from repro.models import build_model
from repro.serve import ServeConfig, generate, batched_serve


def test_all_diffusions_recover_planted_cluster(sbm_graph):
    """Paper's end-to-end contract: diffusion + sweep finds the planted
    low-conductance cluster from an inside seed, for every engine."""
    runs = {
        "pr_nibble": pr_nibble(sbm_graph, 5, eps=1e-7, alpha=0.01).p,
        "nibble": nibble(sbm_graph, 5, eps=1e-8, T=20).p,
        "hk_pr": hk_pr(sbm_graph, 5, N=15, eps=1e-6, t=8.0).p,
    }
    for name, p in runs.items():
        sw = sweep_cut_dense(sbm_graph, p, 1 << 11, 1 << 17)
        members = np.asarray(sw.cluster())[: int(sw.best_size)]
        assert np.mean(members < 100) > 0.85, name
        assert float(sw.best_conductance) < 0.25, name
    # rand-HK-PR via the sparse sweep API
    r = rand_hk_pr(sbm_graph, 5, 8192, 12, 6.0, jax.random.PRNGKey(0))
    sw = sweep_cut(sbm_graph, r.ids, r.vals, r.nnz, 1 << 17)
    members = np.asarray(sw.cluster())[: int(sw.best_size)]
    assert np.mean(members < 100) > 0.8


def test_graph_families_all_build():
    for fam, kw in [("randLocal", dict(n=5000)), ("3D-grid", dict(side=8)),
                    ("rmat", dict(scale=10)), ("sbm", dict(k=4, size=50)),
                    ("ba", dict(n=2000))]:
        g = make_graph(fam, **kw)
        assert g.m > 0
        deg = np.asarray(g.deg)
        assert deg.sum() == 2 * g.m


def test_ncp_dips_at_planted_size(sbm_graph):
    """Fig 10 shape: conductance minimum near the planted cluster size."""
    res = ncp(sbm_graph, num_seeds=16, alphas=(0.01,), epss=(1e-6,),
              batch=16, cap_n=1 << 10, sweep_cap_e=1 << 17)
    best = res.best_conductance
    # best conductance at sizes 80–120 beats sizes ≤ 10 by a wide margin
    near_planted = np.nanmin(best[79:120])
    tiny = np.nanmin(best[:10])
    assert near_planted < tiny * 0.7
    assert near_planted < 0.2


def test_serving_end_to_end():
    cfg = smoke_config("yi-6b")
    m = build_model(cfg, remat=False)
    params = m.init_fn(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    out = generate(m, params, prompts, ServeConfig(max_new_tokens=5))
    assert out.shape == (2, 5)
    # greedy decode is deterministic
    out2 = generate(m, params, prompts, ServeConfig(max_new_tokens=5))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    # continuous batching over a ragged request list
    reqs = [np.arange(5), np.arange(9), np.arange(3), np.arange(7)]
    res = batched_serve(m, params, reqs, batch_slots=2,
                        cfg=ServeConfig(max_new_tokens=3), prompt_len=10)
    assert len(res) == 4 and all(r.shape == (3,) for r in res)
