"""phi-3-vision-4.2b — phi3-mini + CLIP patch frontend STUB
[hf:microsoft/Phi-3-vision-128k-instruct; hf].
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064; 576 patch tokens."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    layer_pattern=("attn",),
    modality="vision", n_modality_tokens=576,
    source="hf:microsoft/Phi-3-vision-128k-instruct (hf); frontend stubbed",
)
