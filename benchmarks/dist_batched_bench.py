"""Sharded batched engine benchmark — exchange volume vs boundary mass.

The distributed engine's performance claim is the locality argument
(Spielman–Teng via PAPERS.md): per round, the bucketed all_to_all moves one
contribution slot per *frontier* edge that crosses a shard boundary, so the
exchange volume is bounded by the partition's boundary mass — never O(n).
This benchmark measures exactly that ratio on a host mesh: it runs the
batched dist driver (`repro.core.batched_dist.batched_dist_pr_nibble`) over
a seed batch and reports

  * ``exchange_per_round`` — cross-shard contribution slots routed per push
    round (averaged over all lanes' rounds), vs
  * ``boundary_edges`` — directed edges crossing shard boundaries (the
    partition's boundary mass, the locality bound), and their ratio.

Because the main benchmark process runs single-device, the measurement runs
in a subprocess with ``--xla_force_host_platform_device_count=8`` (the same
recipe as tests/test_distributed.py), tiny enough for the CI smoke gate.
Emits the usual CSV rows; the returned dict lands in
``BENCH_dist_batched.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
from repro.launch.mesh import make_host_mesh
from repro.graphs import sbm, rand_local, GraphHandle
from repro.core.batched_dist import batched_dist_pr_nibble

cfg = json.loads(os.environ["DIST_BENCH_CFG"])
mesh = make_host_mesh()
if cfg["graph"] == "sbm":
    g = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
else:
    g = rand_local(20_000, degree=5, seed=3)
h = GraphHandle.shard(g, mesh)
pg = h.partitioned()

# boundary mass: directed edges whose endpoints live on different shards
deg = np.asarray(g.deg)
src = np.repeat(np.arange(g.n), deg)
dst = np.asarray(g.indices)[: src.shape[0]]
boundary = int(((src // pg.rows_per) != (dst // pg.rows_per)).sum())

rng = np.random.default_rng(0)
seeds = rng.choice(np.flatnonzero(deg > 0), size=cfg["B"]).astype(np.int32)

t0 = time.perf_counter()
out = batched_dist_pr_nibble(h, seeds, eps=cfg["eps"], alpha=cfg["alpha"],
                             cap_f=cfg["cap_f"], cap_e=cfg["cap_e"],
                             cap_x=cfg["cap_x"])
wall_us = (time.perf_counter() - t0) * 1e6

rounds = int(out.iterations.sum())
exchanged = int(out.exchanged.sum())
res = dict(
    graph=cfg["graph"], n=g.n, m=g.m, num_shards=pg.num_shards, B=cfg["B"],
    wall_us=wall_us, rounds_total=rounds, exchange_total=exchanged,
    exchange_per_round=exchanged / max(rounds, 1),
    boundary_edges=boundary,
    exchange_over_boundary=(exchanged / max(rounds, 1)) / max(boundary, 1),
    buckets=[list(b) for b in out.buckets],
    overflow_lanes=int(out.overflow.sum()),
)
print("RESULT:" + json.dumps(res))
"""


def _src_path() -> str:
    import repro
    return os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))


def run(smoke: bool = False) -> dict:
    cfg = dict(graph="sbm" if smoke else "randLocal",
               B=4 if smoke else 16, eps=1e-5 if smoke else 1e-6,
               alpha=0.05 if smoke else 0.01,
               cap_f=256 if smoke else 1 << 11,
               cap_e=1 << 13 if smoke else 1 << 15,
               cap_x=1 << 11 if smoke else 1 << 13)
    env = dict(os.environ)
    env["PYTHONPATH"] = _src_path() + os.pathsep + env.get("PYTHONPATH", "")
    env["DIST_BENCH_CFG"] = json.dumps(cfg)
    env.pop("XLA_FLAGS", None)   # the child sets its own device count
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode != 0:
        raise RuntimeError(
            f"dist_batched subprocess failed:\n{proc.stderr[-3000:]}")
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    res = json.loads(line[len("RESULT:"):])
    emit(f"dist_batched/{res['graph']}/B={res['B']}_D={res['num_shards']}",
         res["wall_us"],
         f"exch_per_round={res['exchange_per_round']:.1f};"
         f"boundary_edges={res['boundary_edges']};"
         f"exch_over_boundary={res['exchange_over_boundary']:.3f};"
         f"rounds={res['rounds_total']}")
    return res


if __name__ == "__main__":
    print(json.dumps(run(smoke=True), indent=2))
