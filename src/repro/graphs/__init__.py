from .csr import CSRGraph, build_csr, from_edge_list, load_edge_file, ell_pack
from .generators import rand_local, grid3d, rmat, sbm, ba, make_graph
from .partition import PartitionedCSR, partition_rows, degree_reorder
from .handle import GraphHandle, as_handle, as_local_csr

__all__ = [
    "CSRGraph", "build_csr", "from_edge_list", "load_edge_file", "ell_pack",
    "rand_local", "grid3d", "rmat", "sbm", "ba", "make_graph",
    "PartitionedCSR", "partition_rows", "degree_reorder",
    "GraphHandle", "as_handle", "as_local_csr",
]
