"""yi-6b — llama-arch GQA [arXiv:2403.04652; hf].
32L d_model=4096 32H (kv=4) d_ff=11008 vocab=64000."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000,
    layer_pattern=("attn",),
    source="arXiv:2403.04652 (hf)",
)
