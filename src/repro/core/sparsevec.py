"""Sort-merge sparse vectors — the TPU-native replacement for the paper's
concurrent hash table (§3 "Sparse Sets").

The paper stores (vertex → value) in a lock-free linear-probing hash table;
its complexity analysis only needs batched insert/lookup in O(N) work and
O(log N) depth.  On a TPU random probing is hostile, but *sort* is a native
primitive — so a sparse set here is a sorted, sentinel-padded
``(ids, vals)`` pair:

  * lookup  — ``searchsorted`` (O(log cap) per query, vectorized)
  * merge-add — concatenate + sort + adjacent-segment-sum + compaction
    (O((cap+U) log) work, O(log) depth for U updates — the same bounds as a
    batch of hash inserts, and deterministic)

Capacity is static per jit bucket; exceeding it raises the overflow flag and
the driver retries one bucket up (see frontier.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SparseVec", "sv_empty", "sv_lookup", "sv_merge_add",
           "sv_update_existing", "sv_from_pairs"]


class SparseVec(NamedTuple):
    ids: jnp.ndarray       # int32[cap] — sorted; sentinel (n) padded
    vals: jnp.ndarray      # f32[cap]
    count: jnp.ndarray     # int32
    overflow: jnp.ndarray  # bool

    @property
    def cap(self) -> int:
        return self.ids.shape[0]

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.cap, dtype=jnp.int32) < self.count


def sv_empty(cap: int, n: int) -> SparseVec:
    return SparseVec(ids=jnp.full((cap,), n, jnp.int32),
                     vals=jnp.zeros((cap,), jnp.float32),
                     count=jnp.asarray(0, jnp.int32),
                     overflow=jnp.asarray(False))


def sv_from_pairs(ids, vals, valid, cap: int, n: int) -> SparseVec:
    """Build from (possibly duplicated / unsorted) pairs: duplicates summed."""
    return sv_merge_add(sv_empty(cap, n), ids, vals, valid, n)


def sv_lookup(sv: SparseVec, queries: jnp.ndarray, n: int) -> jnp.ndarray:
    """vals for each query id; 0.0 where absent (the paper's ⊥ = 0)."""
    pos = jnp.searchsorted(sv.ids, queries)
    pos = jnp.clip(pos, 0, sv.cap - 1)
    hit = (sv.ids[pos] == queries) & (queries < n)
    return jnp.where(hit, sv.vals[pos], 0.0)


def sv_update_existing(sv: SparseVec, ids, new_vals, valid) -> SparseVec:
    """Overwrite values of keys already present (no structural change)."""
    pos = jnp.clip(jnp.searchsorted(sv.ids, ids), 0, sv.cap - 1)
    hit = valid & (sv.ids[pos] == ids)
    vals = sv.vals.at[jnp.where(hit, pos, sv.cap)].set(
        jnp.where(hit, new_vals, 0.0), mode="drop")
    return sv._replace(vals=vals)


def sv_merge_add(sv: SparseVec, upd_ids, upd_vals, upd_valid, n: int) -> SparseVec:
    """`r[w] += delta` for a batch of updates — the fetchAdd batch.

    Concatenate the live entries with the updates, sort by id, sum adjacent
    duplicates (segment-sum over cumsum-group ids), compact back to `cap`.
    """
    cap = sv.cap
    u = upd_ids.shape[0]
    tot = cap + u
    ids_all = jnp.concatenate([
        jnp.where(sv.valid(), sv.ids, n),
        jnp.where(upd_valid, upd_ids, n).astype(jnp.int32)])
    vals_all = jnp.concatenate([
        jnp.where(sv.valid(), sv.vals, 0.0),
        jnp.where(upd_valid, upd_vals, 0.0)])
    order = jnp.argsort(ids_all)
    ids_s = ids_all[order]
    vals_s = vals_all[order]
    first = jnp.concatenate([jnp.array([True]), ids_s[1:] != ids_s[:-1]])
    group = jnp.cumsum(first) - 1                      # group id per slot
    sums = jax.ops.segment_sum(vals_s, group, num_segments=tot)
    sel = first & (ids_s < n)
    pos = jnp.cumsum(sel) - 1
    new_count = jnp.sum(sel).astype(jnp.int32)
    out_ids = jnp.full((cap,), n, jnp.int32).at[
        jnp.where(sel, pos, cap)].set(ids_s, mode="drop")
    out_vals = jnp.zeros((cap,), jnp.float32).at[
        jnp.where(sel, pos, cap)].set(sums[group], mode="drop")
    return SparseVec(ids=out_ids, vals=out_vals,
                     count=jnp.minimum(new_count, cap),
                     overflow=sv.overflow | (new_count > cap))
