"""Batched sparse backend (core/batched_sparse.py) and the engine's
dense/sparse lane selection (serve/cluster_engine.py).

The contracts under test (docs/algorithms.md §Bit-identity guarantees):
per-seed outputs of ``batched_pr_nibble_sparse`` are *bit-identical* to
single-seed ``pr_nibble_sparse`` — including through the frontier/value
overflow ladder — the sparse sweep equals the rank-table sweep element for
element, and the engine routes requests to the lane type the heuristic (or
an explicit pin) demands while still matching the single-seed drivers.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (pr_nibble_sparse, sweep_cut,
                        sweep_cut_sparse, batched_pr_nibble,
                        batched_pr_nibble_sparse, batched_cluster_sparse,
                        batched_sparse_sweep_cut, sparse_rows_to_dense,
                        sparse_lane_footprint, pick_backend)
from repro.serve import ClusterRequest, LocalClusterEngine

# Right-sized workspaces for the small test graphs (see test_batched.py).
CAPS = dict(cap_f=1 << 10, cap_e=1 << 14, cap_v=1 << 12)
TINY = dict(cap_f=1 << 5, cap_e=1 << 7, cap_v=1 << 6)


def _mixed_params(graph, B, seed=0):
    rng = np.random.default_rng(seed)
    deg = np.asarray(graph.deg)
    seeds = rng.choice(np.flatnonzero(deg > 0), size=B).astype(np.int32)
    eps = rng.choice([1e-5, 1e-6], size=B).astype(np.float32)
    alpha = rng.choice([0.05, 0.01], size=B).astype(np.float32)
    return seeds, eps, alpha


def _assert_lane_matches(out, i, ref):
    """Lane i of a BatchedSparseDiffusionResult == a PRNibbleSparseResult."""
    k = int(out.p_count[i])
    assert k == int(ref.p.count)
    np.testing.assert_array_equal(out.p_ids[i, :k],
                                  np.asarray(ref.p.ids)[:k])
    np.testing.assert_array_equal(out.p_vals[i, :k],
                                  np.asarray(ref.p.vals)[:k])
    kr = int(out.r_count[i])
    assert kr == int(ref.r.count)
    np.testing.assert_array_equal(out.r_ids[i, :kr],
                                  np.asarray(ref.r.ids)[:kr])
    np.testing.assert_array_equal(out.r_vals[i, :kr],
                                  np.asarray(ref.r.vals)[:kr])
    assert int(out.pushes[i]) == int(ref.pushes)
    assert int(out.iterations[i]) == int(ref.iterations)


# ------------------------------------------------- (a) batched == single-seed

def test_batched_sparse_matches_single_seed(local_graph):
    """Mixed (α, ε) lanes, ample caps: every lane bit-identical to the
    single-seed sparse driver, one compiled bucket."""
    B = 16
    seeds, eps, alpha = _mixed_params(local_graph, B)
    out = batched_pr_nibble_sparse(local_graph, seeds, eps, alpha, **CAPS)
    for i in range(B):
        ref = pr_nibble_sparse(local_graph, int(seeds[i]), float(eps[i]),
                               float(alpha[i]), **CAPS)
        _assert_lane_matches(out, i, ref)
    assert not out.overflow.any()


def test_batched_sparse_matches_dense_backend(local_graph):
    """Cross-backend agreement: densified sparse p == dense p (float
    tolerance — reduction orders differ), same push counts."""
    B = 6
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=1)
    sp = batched_pr_nibble_sparse(local_graph, seeds, eps, alpha, **CAPS)
    dn = batched_pr_nibble(local_graph, seeds, eps, alpha,
                           cap_f=1 << 10, cap_e=1 << 14)
    dense = sparse_rows_to_dense(sp.p_ids, sp.p_vals, sp.p_count,
                                 local_graph.n)
    np.testing.assert_allclose(dense, dn.p, atol=1e-6)
    np.testing.assert_array_equal(sp.pushes, dn.pushes)


# ------------------------------------------------- (b) frontier-overflow ladder

def test_sparse_overflow_ladder_promotion(local_graph):
    """Deliberately tiny (cap_f, cap_e, cap_v): every lane overflows the
    first buckets; the generalized ladder (frontier AND value capacity)
    climbs and results still equal the single-seed sparse driver, which
    retries on the same doubling schedule."""
    B = 8
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=4)
    out = batched_pr_nibble_sparse(local_graph, seeds, eps, alpha, **TINY)
    assert not out.overflow.any()
    assert len(out.buckets) > 1          # promotions actually happened
    cap_es = [b[2] for b in out.buckets]
    assert cap_es == sorted(set(cap_es)), "each bucket dispatched once"
    cap_vs = [b[3] for b in out.buckets]
    assert all(v2 >= v1 for v1, v2 in zip(cap_vs, cap_vs[1:]))
    assert max(cap_vs) <= local_graph.n + 1     # cap_v clamps at n+1
    for i in range(B):
        ref = pr_nibble_sparse(local_graph, int(seeds[i]), float(eps[i]),
                               float(alpha[i]), **TINY)
        _assert_lane_matches(out, i, ref)


# ------------------------------------------------- (c) sparse sweep cut

def test_sweep_cut_sparse_matches_rank_table_sweep(local_graph):
    """sweep_cut_sparse (sorted-support lookup, O(cap_n+cap_e) memory)
    returns element-identical arrays to sweep_cut (dense rank table)."""
    for s in (5, 200, 1234):
        res = pr_nibble_sparse(local_graph, s, 1e-6, 0.05, **CAPS)
        a = sweep_cut(local_graph, res.p.ids, res.p.vals, res.p.count, 1 << 15)
        b = sweep_cut_sparse(local_graph, res.p.ids, res.p.vals, res.p.count,
                             1 << 15)
        np.testing.assert_array_equal(np.asarray(a.order), np.asarray(b.order))
        np.testing.assert_array_equal(np.asarray(a.cut), np.asarray(b.cut))
        np.testing.assert_array_equal(np.asarray(a.conductance),
                                      np.asarray(b.conductance))
        assert float(a.best_conductance) == float(b.best_conductance)
        assert int(a.best_size) == int(b.best_size)
        assert int(a.nnz) == int(b.nnz)


def test_batched_sparse_sweep_matches_per_lane(local_graph):
    B = 4
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=3)
    out = batched_pr_nibble_sparse(local_graph, seeds, eps, alpha, **CAPS)
    sw = batched_sparse_sweep_cut(local_graph, jnp.asarray(out.p_ids),
                                  jnp.asarray(out.p_vals),
                                  jnp.asarray(out.p_count), 1 << 15)
    for i in range(B):
        ref = sweep_cut_sparse(local_graph, jnp.asarray(out.p_ids[i]),
                               jnp.asarray(out.p_vals[i]),
                               jnp.asarray(out.p_count[i]), 1 << 15)
        assert float(sw.best_conductance[i]) == float(ref.best_conductance)
        assert int(sw.best_size[i]) == int(ref.best_size)


def test_batched_cluster_sparse_fused(sbm_graph):
    """Fused sparse diffusion+sweep == sparse diffusion then sparse sweep."""
    B = 6
    rng = np.random.default_rng(5)
    seeds = rng.integers(0, sbm_graph.n, size=B).astype(np.int32)
    caps = dict(cap_f=1 << 10, cap_e=1 << 14, cap_v=1 << 10)
    out = batched_cluster_sparse(sbm_graph, seeds, 1e-6, 0.05,
                                 sweep_cap_e=1 << 14, **caps)
    assert not out.overflow.any()
    for i in range(B):
        ref = pr_nibble_sparse(sbm_graph, int(seeds[i]), 1e-6, 0.05, **caps)
        sw = sweep_cut_sparse(sbm_graph, ref.p.ids, ref.p.vals, ref.p.count,
                              1 << 14)
        assert float(out.best_conductance[i]) == float(sw.best_conductance)
        assert int(out.best_size[i]) == int(sw.best_size)
        assert int(out.pushes[i]) == int(ref.pushes)


# ------------------------------------------------- (d) engine backend selection

def test_engine_sparse_backend_matches_single_seed(local_graph):
    """backend="sparse" engine: mixed-parameter burst through sparse lanes,
    every result equal to single-seed sparse driver + sparse sweep."""
    B = 10
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=6)
    reqs = [ClusterRequest(seed=int(s), alpha=float(a), eps=float(e),
                           backend="sparse")
            for s, e, a in zip(seeds, eps, alpha)]
    eng = LocalClusterEngine(local_graph, batch_slots=4, cap_f=1 << 10,
                             cap_e=1 << 14, cap_v=1 << 11, cap_n=1 << 10,
                             sweep_cap_e=1 << 15)
    results = eng.run(reqs)
    assert len(results) == B
    for r, q in zip(results, reqs):
        assert r.request is q
        assert r.backend == "sparse"
        ref = pr_nibble_sparse(local_graph, q.seed, q.eps, q.alpha,
                               cap_f=1 << 10, cap_e=1 << 14, cap_v=1 << 11)
        sw = sweep_cut_sparse(local_graph, ref.p.ids, ref.p.vals,
                              ref.p.count, 1 << 15)
        assert r.pushes == int(ref.pushes)
        assert r.conductance == float(sw.best_conductance)
        assert r.size == int(sw.best_size)
        assert not r.overflow
    assert eng.stats["completed"] == B


def test_engine_auto_backend_heuristic(local_graph):
    """auto mode picks by the graph-size/K rule; explicit pins override."""
    assert pick_backend(2000, 2048) == "dense"     # n < 2*4*2048
    assert pick_backend(2000, 128) == "sparse"     # n >= 2*4*128
    caps = dict(cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                sweep_cap_e=1 << 15)
    # big cap_v -> dense lanes
    eng = LocalClusterEngine(local_graph, batch_slots=2, cap_v=1 << 11, **caps)
    r = eng.run([ClusterRequest(seed=5, eps=1e-5)])[0]
    assert r.backend == "dense"
    # tiny cap_v -> sparse lanes; a dense pin on the same engine overrides
    eng = LocalClusterEngine(local_graph, batch_slots=2, cap_v=1 << 7, **caps)
    ra, rb = eng.run([ClusterRequest(seed=5, eps=1e-5),
                      ClusterRequest(seed=5, eps=1e-5, backend="dense")])
    assert ra.backend == "sparse"
    assert rb.backend == "dense"
    assert ra.pushes == rb.pushes      # same work either lane type
    assert ra.conductance == pytest.approx(rb.conductance, rel=1e-6)
    # hk_pr never rides sparse lanes: auto falls back, a pin is an error
    r = eng.run([ClusterRequest(seed=5, method="hk_pr", eps=1e-5)])[0]
    assert r.backend == "dense"
    # ... and an engine-wide sparse default also falls back (no error)
    eng_sp = LocalClusterEngine(local_graph, batch_slots=2, backend="sparse",
                                cap_v=1 << 7, **caps)
    r = eng_sp.run([ClusterRequest(seed=5, method="hk_pr", eps=1e-5)])[0]
    assert r.backend == "dense"
    with pytest.raises(ValueError, match="sparse"):
        eng.submit(ClusterRequest(seed=5, method="hk_pr", backend="sparse"))
    with pytest.raises(ValueError, match="unknown backend"):
        eng.submit(ClusterRequest(seed=5, backend="dens"))
    with pytest.raises(ValueError, match="unknown backend"):
        LocalClusterEngine(local_graph, backend="sprase")


def test_engine_sparse_overflow_promotion(local_graph):
    """Tiny sparse buckets: requests climb the ladder on sparse lanes and
    match the bucketed single-seed sparse driver."""
    seeds = [5, 105, 205]
    eng = LocalClusterEngine(local_graph, batch_slots=2, backend="sparse",
                             cap_f=1 << 5, cap_e=1 << 7, cap_v=1 << 6,
                             cap_n=1 << 8, sweep_cap_e=1 << 10)
    results = eng.run([ClusterRequest(seed=s, alpha=0.05, eps=1e-5)
                       for s in seeds])
    assert eng.stats["promotions"] > 0
    for r, s in zip(results, seeds):
        ref = pr_nibble_sparse(local_graph, s, 1e-5, 0.05,
                               cap_f=1 << 5, cap_e=1 << 7, cap_v=1 << 6)
        assert r.backend == "sparse"
        assert r.pushes == int(ref.pushes)
        assert not r.overflow
    shapes = eng.stats["bucket_shapes"]
    # (method, backend, ops_backend, B, f, e, topo) — topo None off-mesh
    assert all(len(sh) == 7 for sh in shapes)
    assert all(sh[-1] is None for sh in shapes)


# ------------------------------------------------- (e) memory accounting

def test_sparse_lane_footprint_accounting():
    fp = sparse_lane_footprint(cap_f=1 << 10, cap_e=1 << 14, cap_v=1 << 12)
    assert fp["state"] == 4 * (1 << 12)           # p,r × (ids, vals)
    assert fp["total"] == fp["state"] + fp["transient"]
    # the memory-bound claim: state is K-bounded, independent of any n
    assert fp["state"] < 2 * 50_000               # dense lane on randLocal-50k
