"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs a real training loop (CPU-sized by default via --smoke) with the full
production plumbing: deterministic sharded data, jit'd train step, async
checkpointing, heartbeat, resume-from-latest.  ``--devices N`` requests N
host devices (must be set before jax init, hence the env fiddle at top).
"""
import argparse
import os
import sys


def _early_args():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")


_early_args()

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import (AdamWConfig, Checkpointer, adamw_init,  # noqa: E402
                         latest_step, load_pytree, make_train_step, Heartbeat)
from repro.data import DataConfig, TokenPipeline  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compress-grads", default=None, choices=[None, "int8"])
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, remat=True)
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps,
                      compress_grads=args.compress_grads)
    step_fn = jax.jit(make_train_step(model, ocfg), donate_argnums=(0, 1))

    pipe = TokenPipeline(DataConfig(
        vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq,
        seed=args.seed, enc_seq=cfg.enc_seq if cfg.enc_dec else 0,
        n_modality_tokens=cfg.n_modality_tokens, d_model=cfg.d_model))

    key = jax.random.PRNGKey(args.seed)
    params = model.init_fn(key)
    opt = adamw_init(params)
    start = 0
    # resume if a committed checkpoint exists
    if latest_step(args.ckpt_dir) is not None:
        tmpl = {"params": params, "opt": opt}
        restored, start = load_pytree(tmpl, args.ckpt_dir)
        params, opt = restored["params"], restored["opt"]
        start += 1
        print(f"resumed from step {start - 1}")

    ck = Checkpointer(args.ckpt_dir, keep=3)
    hb = Heartbeat(os.path.join(args.ckpt_dir, "hb"), host_id=0)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.arch_id} params={n_params:,} steps={args.steps}")

    for i in range(start, args.steps):
        params, opt, metrics = step_fn(params, opt, pipe.get_batch(i))
        hb.beat()
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if i and i % args.ckpt_every == 0:
            ck.save({"params": params, "opt": opt}, i)
    ck.save({"params": params, "opt": opt}, args.steps - 1, blocking=True)
    ck.close()
    print("done")


if __name__ == "__main__":
    main()
