"""jit'd public wrappers around the Pallas kernels.

These own the layout work (ELL packing, sort-and-bucket, padding) so callers
deal in graph/CSR terms; on non-TPU backends they flip ``interpret=True``
automatically (the kernels execute in the Pallas interpreter for parity
testing — TPU is the compile target).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from .ell_spmv import band_spmv, ROW_BLOCK
from .scatter_accum import scatter_accum_tiles, TILE
from .prefix_scan import block_scan, BLOCK

__all__ = ["on_tpu", "diffusion_spmv", "scatter_add_via_mxu",
           "scatter_fold_via_mxu", "prefix_sum", "prefix_sum_exact",
           "pack_banded_ell"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def pack_banded_ell(graph, halo: int = 1, coef: float = 0.5):
    """Split a CSR graph into (banded-ELL part, escaper COO part).

    Band-resident edges (|block(src) − block(dst)| ≤ halo) go to the ELL
    table consumed by the kernel; the rest go to a COO list handled by an
    XLA scatter — the hybrid layout described in ell_spmv.py.

    The kernel *gathers*: y[v] = Σ_k wgt[v,k]·p[nbr[v,k]], so the diffusion
    push into v along edge (w → v) carries weight coef/d(w) — the
    **neighbor's** degree (coef=0.5 for the lazy-walk half-push).  Gather
    over the symmetric adjacency is exactly the push accumulation, without
    any scatter in the hot path.
    """
    g = graph.to_numpy()
    n = g.n
    n_pad = -(-n // ROW_BLOCK) * ROW_BLOCK
    src = np.repeat(np.arange(n), g.deg)
    dst = g.indices[: 2 * g.m]
    in_band = np.abs(src // ROW_BLOCK - dst // ROW_BLOCK) <= halo
    # ELL width = max band-degree
    band_deg = np.bincount(src[in_band], minlength=n_pad).astype(np.int64)
    W = max(int(band_deg.max()), 1)
    nbr = np.full((n_pad, W), n_pad, dtype=np.int32)
    wgt = np.zeros((n_pad, W), dtype=np.float32)
    slot = np.zeros(n_pad, dtype=np.int64)
    bs, bd = src[in_band], dst[in_band]
    for s, d in zip(bs, bd):
        nbr[s, slot[s]] = d
        wgt[s, slot[s]] = coef / g.deg[d]   # neighbor's degree: push d → s
        slot[s] += 1
    esc_src = src[~in_band].astype(np.int32)
    esc_dst = dst[~in_band].astype(np.int32)
    esc_w = (coef / g.deg[esc_dst]).astype(np.float32)
    return (jnp.asarray(nbr), jnp.asarray(wgt),
            jnp.asarray(esc_src), jnp.asarray(esc_dst), jnp.asarray(esc_w),
            n_pad, W)


@functools.partial(jax.jit, static_argnames=("halo",))
def diffusion_spmv(nbr, wgt, esc_src, esc_dst, esc_w, p, halo: int = 1):
    """One saturated diffusion product y = coef·(A D⁻¹)p on the hybrid layout:
    banded ELL via the Pallas kernel + escaper COO via XLA scatter."""
    y = band_spmv(nbr, wgt, p, halo=halo, interpret=_interp())
    contrib = esc_w * p[esc_dst]            # gather semantics: pull d → s
    return y.at[esc_src].add(contrib)


def scatter_add_via_mxu(vec: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                        chunk: int = 256) -> jnp.ndarray:
    """Dense scatter-add through the sort-bucket-MXU pipeline.

    Sorts (idx, vals) by destination, buckets into 128-wide tiles with a
    fixed per-tile chunk, runs the Pallas accumulation kernel, and adds the
    tile updates back with one contiguous reshape — semantically equal to
    ``vec.at[idx].add(vals)`` (ref: kernels/ref.py::scatter_accum_ref).

    Per-tile overflow (more than ``chunk`` contributions landing in one
    tile) falls back to XLA scatter for the overflowing remainder.
    """
    n = vec.shape[0]
    n_pad = -(-n // TILE) * TILE
    T = n_pad // TILE
    order = jnp.argsort(idx)
    idx_s = idx[order]
    vals_s = vals[order]
    tile_id = jnp.clip(idx_s // TILE, 0, T - 1)
    # rank within tile: position - first position of tile
    first_pos = jnp.searchsorted(tile_id, jnp.arange(T), side="left")
    rank = jnp.arange(idx.shape[0]) - first_pos[tile_id]
    ok = (idx_s >= 0) & (idx_s < n) & (rank < chunk)
    flat = tile_id * chunk + rank
    local = jnp.full((T * chunk,), -1, jnp.int32).at[
        jnp.where(ok, flat, T * chunk)].set(
        (idx_s % TILE).astype(jnp.int32), mode="drop")
    v = jnp.zeros((T * chunk,), jnp.float32).at[
        jnp.where(ok, flat, T * chunk)].set(vals_s, mode="drop")
    tiles = scatter_accum_tiles(local.reshape(T, chunk), v.reshape(T, chunk),
                                interpret=_interp())
    out = vec + tiles.reshape(-1)[:n]
    # overflow remainder via XLA scatter (rare; correctness-preserving)
    spill = (~ok) & (idx_s >= 0) & (idx_s < n)
    out = out.at[jnp.where(spill, idx_s, n)].add(
        jnp.where(spill, vals_s, 0.0), mode="drop")
    return out


def scatter_fold_via_mxu(vec: jnp.ndarray, idx: jnp.ndarray,
                         vals: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Update-order-preserving scatter-add through the MXU kernel.

    Same sort-bucket-matmul pipeline as :func:`scatter_add_via_mxu`, but each
    128-wide destination tile's *existing* ``vec`` values are prepended as the
    tile's first 128 (identity-offset) contributions, so every output element
    is the left fold ``((vec[i] + v_1) + v_2) + …`` with the contributions in
    their original submission order (the stable sort preserves it) — exactly
    the combine order of ``vec.at[idx].add(vals)``.  This is the bit-exact
    variant :mod:`repro.core.ops` routes drivers through; the plain
    ``vec + tiles`` variant above keeps the cheaper layout for callers that
    only need allclose.

    Per-tile overflow (more than ``chunk`` contributions on one tile) spills
    to an XLA scatter *after* the tile fold — those are the latest-sorted
    contributions per destination, so fold order is still preserved.
    """
    n = vec.shape[0]
    m = idx.shape[0]
    n_pad = -(-n // TILE) * TILE
    T = n_pad // TILE
    C = TILE + chunk
    order = jnp.argsort(idx)               # stable: preserves submission order
    idx_s = idx[order]
    vals_s = vals[order]
    tile_id = jnp.clip(idx_s // TILE, 0, T - 1)
    first_pos = jnp.searchsorted(tile_id, jnp.arange(T), side="left")
    rank = jnp.arange(m) - first_pos[tile_id]
    ok = (idx_s >= 0) & (idx_s < n) & (rank < chunk)
    # identity block: slot j < TILE of tile t carries vec[t*TILE + j]
    local = jnp.broadcast_to(
        jnp.concatenate([jnp.arange(TILE, dtype=jnp.int32),
                         jnp.full((chunk,), -1, jnp.int32)]), (T, C))
    v = jnp.concatenate(
        [jnp.pad(vec.astype(jnp.float32), (0, n_pad - n)).reshape(T, TILE),
         jnp.zeros((T, chunk), jnp.float32)], axis=1)
    flat = tile_id * C + TILE + rank
    local = local.reshape(-1).at[jnp.where(ok, flat, T * C)].set(
        (idx_s % TILE).astype(jnp.int32), mode="drop").reshape(T, C)
    v = v.reshape(-1).at[jnp.where(ok, flat, T * C)].set(
        vals_s.astype(jnp.float32), mode="drop").reshape(T, C)
    tiles = scatter_accum_tiles(local, v, interpret=_interp())
    out = tiles.reshape(-1)[:n]
    spill = (~ok) & (idx_s >= 0) & (idx_s < n)
    out = out.at[jnp.where(spill, idx_s, n)].add(
        jnp.where(spill, vals_s.astype(jnp.float32), 0.0), mode="drop")
    return out


def prefix_sum(x: jnp.ndarray) -> jnp.ndarray:
    """Inclusive prefix sum via the blocked Pallas scan (auto-padded)."""
    n = x.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    xp = jnp.pad(x.astype(jnp.float32), (0, n_pad - n))
    return block_scan(xp, interpret=_interp())[:n]


def prefix_sum_exact(x: jnp.ndarray) -> jnp.ndarray:
    """Dtype-preserving inclusive prefix sum via the blocked Pallas scan.

    Unlike :func:`prefix_sum` there is no f32 cast: integer inputs scan in
    integer arithmetic, so the result is bit-identical to ``jnp.cumsum``
    regardless of the block association (the op layer's exactness contract
    for the drivers' int32 scans)."""
    n = x.shape[0]
    n_pad = -(-n // BLOCK) * BLOCK
    xp = jnp.pad(x, (0, n_pad - n))
    return block_scan(xp, interpret=_interp())[:n]
