"""Parallel evolving sets (paper §4.6, Andersen–Peres) — for completeness.

The paper implements ES sequentially, observes it is "not very useful in
practice" as stated in [7], and sketches the parallelization: steps 1–2 are
O(1); step 3 (S' = {v : p(v,S) ≥ Z}) is a parallel filter over S ∪ ∂S with
prefix-sum maintenance of vol(S) and |∂(S)|.  We implement exactly that
sketch: per round, expand S, scatter-count e(v,S), threshold against the
random Z, repack.  Work O(B), depth O(T log n).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from . import ops
from .frontier import (Frontier, expand, pack_unique, singleton,
                       scatter_add_dense, scatter_set_dense)

__all__ = ["EvolvingSetsResult", "evolving_sets"]


class EvolvingSetsResult(NamedTuple):
    ids: jnp.ndarray          # int32[cap_s] — members of final S (sentinel pad)
    count: jnp.ndarray        # int32
    conductance: jnp.ndarray  # f32
    iterations: jnp.ndarray   # int32
    work: jnp.ndarray         # int32 — edges traversed (cost bound B counter)
    overflow: jnp.ndarray     # bool


class _State(NamedTuple):
    S: Frontier
    x_walk: jnp.ndarray
    key: jax.Array
    t: jnp.ndarray
    work: jnp.ndarray
    cond_val: jnp.ndarray
    done: jnp.ndarray
    overflow: jnp.ndarray


@functools.partial(jax.jit, static_argnums=(2, 5, 6),
                   static_argnames=("T", "cap_s", "cap_e", "backend"))
def evolving_sets(graph: CSRGraph, x, T: int, B, phi,
                  cap_s: int = 1 << 12, cap_e: int = 1 << 16,
                  key: jax.Array = None, *,
                  backend: str = "xla") -> EvolvingSetsResult:
    if key is None:
        key = jax.random.PRNGKey(0)
    n, m = graph.n, graph.m
    deg = graph.deg

    def set_stats(S: Frontier):
        """vol(S), ∂(S), φ(S) via one expansion + membership mask."""
        svalid = S.valid()
        sids = jnp.where(svalid, S.ids, n)
        in_S = scatter_set_dense(jnp.zeros((n + 1,), bool), sids, svalid,
                                 svalid)
        eb = expand(graph, S, cap_e, backend=backend)
        cut = jnp.sum(eb.valid & ~in_S[jnp.minimum(eb.dst, n)])
        vol = jnp.sum(jnp.where(svalid, deg[jnp.minimum(sids, n - 1)], 0))
        denom = jnp.minimum(vol, 2 * m - vol)
        cond_val = jnp.where(denom > 0, cut / jnp.maximum(denom, 1), jnp.inf)
        return vol, cut, cond_val, eb, in_S

    def cond(s: _State):
        return (~s.done) & (~s.overflow) & (s.t < T) & (s.work < B)

    def body(s: _State) -> _State:
        key, k_walk, k_stay, k_z = jax.random.split(s.key, 4)

        # step 1: lazy walk update for x_walk
        d_x = deg[s.x_walk]
        off = jnp.floor(jax.random.uniform(k_walk) * d_x).astype(jnp.int32)
        nxt = graph.indices[jnp.clip(graph.indptr[s.x_walk] + off, 0,
                                     graph.indices.shape[0] - 1)]
        move = (jax.random.uniform(k_stay) >= 0.5) & (d_x > 0)
        x_walk = jnp.where(move, nxt, s.x_walk)

        # e(v, S) for v ∈ S ∪ ∂S: scatter-count over S's edges through the
        # op layer (shared drop-sentinel convention, backend-dispatched)
        vol, _, _, eb, in_S = set_stats(s.S)
        e_vS = scatter_add_dense(jnp.zeros((n + 1,), jnp.int32), eb.dst,
                                 jnp.ones(eb.dst.shape, jnp.int32), eb.valid,
                                 backend=backend)

        def p_vS(v):
            dv = jnp.maximum(deg[jnp.minimum(v, n - 1)], 1)
            base = e_vS[jnp.minimum(v, n)] / (2.0 * dv)
            return base + 0.5 * in_S[jnp.minimum(v, n)]

        # step 2: Z ~ U[0, p(x_walk, S)]
        z = jax.random.uniform(k_z) * p_vS(x_walk)

        # step 3: S' = {v ∈ S ∪ ∂S : p(v,S) ≥ Z}  (parallel filter)
        svalid = s.S.valid()
        cands = jnp.concatenate([jnp.where(svalid, s.S.ids, n), eb.dst])
        cvalid = jnp.concatenate([svalid, eb.valid])
        keep = cvalid & (p_vS(cands) >= z) & (deg[jnp.minimum(cands, n - 1)] > 0)
        S_new = pack_unique(cands, keep, n, cap_s, backend=backend)

        # step 4: stop on φ(S') < φ  (T / B limits are in `cond`)
        _, _, cond_new, eb2, _ = set_stats(S_new)
        work = s.work + eb.total + eb2.total
        empty = S_new.count == 0
        return _State(
            S=Frontier(ids=jnp.where(empty, s.S.ids, S_new.ids),
                       count=jnp.where(empty, s.S.count, S_new.count),
                       overflow=S_new.overflow & ~empty),
            x_walk=x_walk, key=key, t=s.t + 1, work=work,
            cond_val=jnp.where(empty, s.cond_val, cond_new),
            done=(cond_new < phi) & ~empty,
            overflow=s.overflow | (S_new.overflow & ~empty) | eb.overflow,
        )

    S0 = singleton(x, n, cap_s)
    _, _, cond0, _, _ = set_stats(S0)
    s0 = _State(S=S0, x_walk=jnp.asarray(x, jnp.int32), key=key,
                t=jnp.asarray(0, jnp.int32), work=jnp.asarray(0, jnp.int32),
                cond_val=cond0, done=jnp.asarray(False),
                overflow=jnp.asarray(False))
    s = jax.lax.while_loop(cond, body, s0)
    return EvolvingSetsResult(ids=s.S.ids, count=s.S.count,
                              conductance=s.cond_val, iterations=s.t,
                              work=s.work, overflow=s.overflow)
