"""Blocked prefix-sum Pallas kernel — the sweep cut's backbone.

Prefix sum is one of the paper's three foundational primitives (§3) and the
core of Theorem 1's sweep cut (cut sizes, volumes, and the final prefix-min
are all scans).  XLA lowers ``cumsum`` to O(n log n) shifted adds or a
serialized loop; this kernel is the classic two-phase work-efficient scan
mapped to TPU VMEM blocks:

  phase 1 — per-block inclusive scan + block total   (this kernel, grid pass)
  phase 2 — tiny exclusive scan of block totals      (jnp on <= grid elems)
  phase 3 — add block offsets                        (this kernel again)

Work O(n), depth O(log n) — Blelloch's bounds, realized with VMEM-resident
blocks of 8·128 lanes × UNROLL rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_scan", "BLOCK"]

BLOCK = 2048  # elements per VMEM block (16 sublane rows × 128 lanes)


def _scan_block_kernel(x_ref, y_ref, tot_ref):
    x = x_ref[...]
    y = jnp.cumsum(x)
    y_ref[...] = y
    tot_ref[0] = y[-1]


def _add_offsets_kernel(y_ref, off_ref, out_ref):
    out_ref[...] = y_ref[...] + off_ref[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def block_scan(x: jnp.ndarray, interpret: bool = False) -> jnp.ndarray:
    """Inclusive prefix sum of f32[n] (n multiple of BLOCK)."""
    n = x.shape[0]
    assert n % BLOCK == 0, f"pad input to a multiple of {BLOCK}"
    nb = n // BLOCK

    y, totals = pl.pallas_call(
        _scan_block_kernel,
        out_shape=(jax.ShapeDtypeStruct((n,), x.dtype),
                   jax.ShapeDtypeStruct((nb,), x.dtype)),
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((BLOCK,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))),
        interpret=interpret,
    )(x)

    # phase 2: exclusive scan of the nb block totals (tiny)
    offsets = jnp.cumsum(totals) - totals

    return pl.pallas_call(
        _add_offsets_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=interpret,
    )(y, offsets)
