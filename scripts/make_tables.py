"""Regenerate the EXPERIMENTS.md §Dry-run/§Roofline tables from the cached
dry-run JSONs.  Usage: PYTHONPATH=src python scripts/make_tables.py"""
import glob
import json
import os

DRY = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if b < 1024 or unit == "TB":
            return f"{b:.1f}{unit}" if unit != "B" else f"{int(b)}B"
        b /= 1024.0


def load():
    cells = {}
    for f in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        d = json.load(open(f))
        cells[d["cell"]] = d
    return cells


def roofline_table(cells, mesh="pod", variants=False):
    rows = []
    hdr = ("| cell | bottleneck | compute_s | memory_s | collective_s | "
           "MODEL/HLO flops | roofline frac | peak HBM frac |")
    sep = "|" + "---|" * 8
    rows.append(hdr)
    rows.append(sep)
    for key in sorted(cells):
        d = cells[key]
        is_variant = key.count("__") >= 3
        if d.get("skipped"):
            if (f"__{mesh}" in key) and not is_variant:
                rows.append(f"| {key} | SKIP | — | — | — | — | — | — "
                            f"({d['reason']}) |")
            continue
        if "error" in d:
            rows.append(f"| {key} | ERROR | — | — | — | — | — | — |")
            continue
        if variants != is_variant or f"__{mesh}" not in key:
            continue
        ratio = d.get("useful_flops_ratio", 0)
        rows.append(
            f"| {key} | {d['bottleneck'].replace('_s','')} "
            f"| {d['compute_s']:.3f} | {d['memory_s']:.3f} "
            f"| {d['collective_s']:.3f} | {ratio:.3f} "
            f"| {d.get('roofline_fraction', 0)*100:.2f}% "
            f"| {d['peak_hbm_frac']:.2f} |")
    return "\n".join(rows)


def dryrun_table(cells):
    rows = ["| cell | chips | lower+compile (s) | per-chip HBM "
            "(args+temp+out) | collective bytes/chip | status |",
            "|" + "---|" * 6]
    for key in sorted(cells):
        d = cells[key]
        if key.count("__") >= 3 and "graph" not in key:
            continue
        if d.get("skipped"):
            rows.append(f"| {key} | — | — | — | — | SKIP: {d['reason']} |")
        elif "error" in d:
            rows.append(f"| {key} | — | — | — | — | ERROR |")
        else:
            hbm = (d["argument_bytes"] + d["temp_bytes"] + d["output_bytes"])
            rows.append(
                f"| {key} | {d.get('chips', d.get('num_chips','?'))} "
                f"| {d.get('lower_s', 0)}+{d.get('compile_s', 0)} "
                f"| {fmt_bytes(hbm)} | {fmt_bytes(d['collective_bytes'])} "
                f"| ok |")
    return "\n".join(rows)


if __name__ == "__main__":
    cells = load()
    n_ok = sum(1 for d in cells.values()
               if not d.get("skipped") and "error" not in d)
    n_skip = sum(1 for d in cells.values() if d.get("skipped"))
    n_err = sum(1 for d in cells.values() if "error" in d)
    print(f"cells: {n_ok} ok, {n_skip} skipped, {n_err} errors\n")
    print("## Dry-run table\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single pod)\n")
    print(roofline_table(cells, "pod"))
    print("\n## Roofline (multi-pod)\n")
    print(roofline_table(cells, "multipod"))
    print("\n## Variants\n")
    print(roofline_table(cells, "pod", variants=True))
