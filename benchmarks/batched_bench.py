"""Batched multi-seed engine benchmark (the PR's headline claim).

Paper §5: the practical win of parallel local clustering is amortizing many
seed queries.  Three ways to answer B queries:

  loop     — B single-seed ``pr_nibble`` calls (one dispatch per seed)
  batched  — one ``batched_pr_nibble`` call (one dispatch per capacity bucket)
  engine   — ``LocalClusterEngine`` continuous batching with mixed (α, ε)
             and a sweep cut per request (the serving workload)

Reports µs per batch and per seed; `loop_over_batched` is the dispatch
amortization factor.
"""
import numpy as np

from repro.core import pr_nibble, batched_pr_nibble
from repro.serve import ClusterRequest, LocalClusterEngine
from .common import get_graph, emit, timeit


def run(smoke: bool = False):
    name = "sbm-planted" if smoke else "randLocal-50k"
    B = 8 if smoke else 32
    eps, alpha = 1e-6, 0.01
    # smoke = one cold run each, workspaces sized for the small graph
    caps = dict(cap_f=1 << 10, cap_e=1 << 14) if smoke else {}
    prime = not smoke
    g = get_graph(name)
    rng = np.random.default_rng(0)
    seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                       size=B).astype(np.int32)

    def loop():
        return [pr_nibble(g, int(s), eps, alpha, **caps) for s in seeds]

    us_loop, _ = timeit(loop, repeats=1, prime=prime)
    us_bat, out = timeit(batched_pr_nibble, g, seeds, eps, alpha,
                         repeats=1, prime=prime, **caps)
    emit(f"batched/{name}/loop_B={B}", us_loop,
         f"per_seed_us={us_loop / B:.1f}")
    emit(f"batched/{name}/batched_B={B}", us_bat,
         f"per_seed_us={us_bat / B:.1f};buckets={len(out.buckets)};"
         f"loop_over_batched={us_loop / max(us_bat, 1e-9):.2f}")

    reqs = [ClusterRequest(seed=int(s), alpha=float(rng.choice([0.05, 0.01])),
                           eps=float(rng.choice([1e-5, 1e-6])))
            for s in seeds]
    eng_caps = (dict(cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                     sweep_cap_e=1 << 14) if smoke else {})
    eng = LocalClusterEngine(g, batch_slots=min(B, 16) if not smoke else 4,
                             **eng_caps)
    if prime:
        # warm the compile cache on the same engine, then zero the counters
        # so the emitted stats describe only the timed run
        eng.run(reqs)
        for key in ("steps", "injections", "promotions", "completed"):
            eng.stats[key] = 0
    us_eng, res = timeit(eng.run, reqs, repeats=1, prime=False)
    mean_cond = float(np.mean([r.conductance for r in res]))
    emit(f"batched/{name}/engine_B={B}", us_eng,
         f"per_seed_us={us_eng / B:.1f};steps={eng.stats['steps']};"
         f"mean_cond={mean_cond:.4f}")


if __name__ == "__main__":
    run()
