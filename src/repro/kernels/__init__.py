"""Pallas TPU kernels for the paper's compute hot-spots.

  ell_spmv      — banded ELL SpMV (saturated diffusion round), one-hot MXU gather
  scatter_accum — sort-bucketed scatter-add (fetchAdd → systolic contraction)
  prefix_scan   — two-phase blocked prefix sum (sweep-cut backbone)
  segment_merge — fused sorted-segment merge (sv_merge_add's post-sort pass)

``ops`` holds the jit'd layout wrappers, ``ref`` the pure-jnp oracles.
Kernels compile for TPU; on CPU they run under ``interpret=True``.  Drivers
never import these directly — they dispatch through :mod:`repro.core.ops`.
"""
from . import ops, ref
from .ell_spmv import band_spmv, ROW_BLOCK
from .scatter_accum import scatter_accum_tiles, TILE
from .prefix_scan import block_scan, BLOCK
from .segment_merge import segment_merge_sorted, segment_merge_stream, BLK

__all__ = ["ops", "ref", "band_spmv", "ROW_BLOCK", "scatter_accum_tiles",
           "TILE", "block_scan", "BLOCK", "segment_merge_sorted",
           "segment_merge_stream", "BLK"]
