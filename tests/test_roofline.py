"""HLO cost walker: matches XLA cost_analysis on scan-free programs and
multiplies scan bodies by trip count (which cost_analysis does not)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlocost import analyze_hlo
from repro.launch.roofline import HW, collective_bytes, cost_analysis_dict


def test_walker_matches_xla_on_scan_free():
    def g(a, b):
        h = jnp.einsum("ij,jk->ik", a, b)
        return jax.nn.relu(h) @ b.T

    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    c = jax.jit(g).lower(a, b).compile()
    ca = cost_analysis_dict(c)
    walk = analyze_hlo(c.as_text())
    # jaxlib's elementwise/fusion accounting drifts across versions (this
    # one counts the relu's flops and its fused intermediate's bytes); the
    # walker tracks the matmul-dominated totals.
    assert walk.flops == pytest.approx(ca["flops"], rel=0.01)
    assert walk.bytes == pytest.approx(ca["bytes accessed"], rel=0.3)


def test_walker_multiplies_scan_trip_count():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = jax.jit(f).lower(w, w).compile()
    walk = analyze_hlo(c.as_text())
    one_matmul = 2 * 512 ** 3
    assert 10 * one_matmul <= walk.flops <= 10.2 * one_matmul
    # XLA itself reports ~1 matmul
    assert cost_analysis_dict(c)["flops"] < 2 * one_matmul


def test_walker_sliced_scan_bytes_not_inflated():
    """Reading one row per scan step must cost ~rows, not trips×matrix."""
    def f(big):
        def body(c, i):
            return c + jax.lax.dynamic_slice_in_dim(big, i, 1, 0)[0], None
        out, _ = jax.lax.scan(body, jnp.zeros((1024,)), jnp.arange(64))
        return out

    big = jax.ShapeDtypeStruct((64, 1024), jnp.float32)
    c = jax.jit(f).lower(big).compile()
    walk = analyze_hlo(c.as_text())
    matrix_bytes = 64 * 1024 * 4
    assert walk.bytes < 12 * matrix_bytes  # not 64× the matrix


def test_hw_terms():
    hw = HW()
    assert hw.peak_flops == 197e12
    assert hw.hbm_bw == 819e9
    assert hw.link_bw == 50e9
