"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function computes the same mathematical object as its kernel with plain
jax.numpy — no tiling, no VMEM reasoning — and is what the per-kernel
shape/dtype sweep tests assert against (``tests/test_kernels.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["band_spmv_ref", "scatter_accum_ref", "block_scan_ref",
           "spmv_csr_ref"]


def band_spmv_ref(nbr: jnp.ndarray, weights: jnp.ndarray,
                  p: jnp.ndarray) -> jnp.ndarray:
    """y[v] = Σ_k weights[v,k] · p[nbr[v,k]]; sentinel ids carry weight 0."""
    n = p.shape[0]
    safe = jnp.clip(nbr, 0, n - 1)
    vals = p[safe] * (nbr < n) * (nbr >= 0)
    return jnp.sum(vals * weights, axis=1)


def scatter_accum_ref(local: jnp.ndarray, vals: jnp.ndarray,
                      tile: int = 128) -> jnp.ndarray:
    """out[t, c] = Σ_j vals[t, j] · [local[t, j] == c]."""
    T, C = local.shape
    out = jnp.zeros((T, tile), jnp.float32)
    ok = (local >= 0) & (local < tile)
    t_idx = jnp.repeat(jnp.arange(T), C)
    c_idx = jnp.where(ok, local, 0).reshape(-1)
    v = jnp.where(ok, vals, 0.0).reshape(-1)
    return out.at[t_idx, c_idx].add(v)


def block_scan_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x)


def spmv_csr_ref(indptr, indices, deg, p, coef: float = 0.5):
    """Dense reference for the full diffusion matrix–vector product
    p' = coef·(A D⁻¹)p (+ the self term added by the caller)."""
    n = deg.shape[0]
    out = jnp.zeros_like(p)
    src = jnp.repeat(jnp.arange(n), deg, total_repeat_length=indices.shape[0])
    contrib = coef * p[src] / jnp.maximum(deg[src], 1)
    return out.at[indices].add(contrib)
