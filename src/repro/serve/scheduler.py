"""Deadline-aware asynchronous serving on top of ``LocalClusterEngine``.

Local clustering does work proportional to the *cluster*, not the graph —
which makes per-query latency wildly heterogeneous: one request drains in a
couple of push rounds while its neighbor runs thousands.  A drain-everything
loop (``LocalClusterEngine.run``) is the wrong shape for that regime; this
module adds the scheduler brain:

  * **Futures-based submission** — ``submit(req, deadline_ms=…, priority=…)``
    returns a :class:`ClusterFuture` (``done()/result(timeout)/
    add_done_callback()``) immediately; callers interleave their own work.
  * **EDF tick planner** — each scheduler tick orders pool stepping by
    *slack*: the earliest resident deadline minus now minus the pool's
    estimated time-to-drain.  The cost model is measured, not guessed:
    per-pool EMA of tick wall time (fed to and read back from the
    :class:`~repro.serve.telemetry.MetricsRegistry`) × the pool's
    pending-ticks estimate (rounds-remaining hints from
    ``repro.core.batched`` / ``repro.core.batched_sparse``).
  * **Deadline expiry** — an overdue request is *harvested*, not abandoned:
    a resident lane is swept as-is into a best-effort partial result, a
    still-queued request completes empty; either way the future resolves
    with ``result.deadline_missed=True`` instead of silently finishing late.
    A request that completes naturally but after its deadline is delivered
    in full, also flagged.
  * **Admission control** — at most ``max_queue`` requests in flight;
    ``submit`` raises :class:`QueueFull` beyond that (backpressure, never
    unbounded buffering).
  * **Drive modes** — ``serve_forever()`` starts a daemon thread running
    the tick loop; or call :meth:`AsyncClusterEngine.tick` yourself for
    deterministic single-threaded driving (what the tests do).

Scheduling never changes answers (docs/algorithms.md, guarantee #3): the
planner only chooses *when* each pool's lanes step, and every lane steps the
same round function through the same trajectory regardless of interleaving.
A stream served with no deadlines is bit-identical, per request, to
``LocalClusterEngine.run()`` on the same requests.

Threading contract: ``submit``/``ClusterFuture`` are thread-safe; the engine
itself is single-threaded and is only ever touched under ``_engine_lock``
(by the drive thread, or by whoever calls ``tick()``).  Callbacks run on the
resolving (drive) thread — keep them short.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.graphs.csr import CSRGraph
from repro.graphs.handle import GraphHandle
from repro.graphs.partition import PartitionedCSR
from .cluster_engine import (ClusterRequest, ClusterResult,
                             LocalClusterEngine)
from .telemetry import MetricsRegistry, load_cost_table, lookup_cost, \
    pool_label
from .tracing import RequestTrace, Tracer

__all__ = ["AsyncClusterEngine", "ClusterFuture", "QueueFull"]


class QueueFull(RuntimeError):
    """Admission control: the scheduler already holds ``max_queue`` unresolved
    requests.  Back off and resubmit — the bound is backpressure, not an
    error in the request."""


class ClusterFuture:
    """Handle for one submitted request; resolves to a :class:`ClusterResult`.

    The deliberately-small subset of ``concurrent.futures.Future`` the
    serving workload needs: ``done()``, blocking ``result(timeout)``, and
    ``add_done_callback(fn)`` (called with the future, on the resolving
    thread; immediately if already resolved).  ``latency_ms`` is the
    submit→resolve wall time once done.
    """

    def __init__(self, request: ClusterRequest) -> None:
        self.request = request
        self.ticket: Optional[int] = None     # engine ticket, set at admission
        self.trace: Optional[RequestTrace] = None  # set when tracing is on
        self.submitted = time.monotonic()     # deadline/latency anchor
        self.latency_ms: Optional[float] = None
        self._cond = threading.Condition()
        self._result: Optional[ClusterResult] = None
        self._done = False
        self._callbacks: List[Callable[["ClusterFuture"], None]] = []

    def done(self) -> bool:
        with self._cond:
            return self._done

    def result(self, timeout: Optional[float] = None) -> ClusterResult:
        """Block until resolved (or ``timeout`` seconds → ``TimeoutError``)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._done, timeout=timeout):
                raise TimeoutError(
                    f"request (seed={self.request.seed}) not done "
                    f"after {timeout}s")
            return self._result

    def add_done_callback(self,
                          fn: Callable[["ClusterFuture"], None]) -> None:
        with self._cond:
            if not self._done:
                self._callbacks.append(fn)
                return
        fn(self)

    def _resolve(self, result: ClusterResult, latency_ms: float) -> None:
        with self._cond:
            self._result = result
            self.latency_ms = latency_ms
            self._done = True
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for fn in callbacks:
            try:
                fn(self)
            except Exception:       # callbacks must not kill the drive loop
                import traceback
                traceback.print_exc()


@dataclasses.dataclass
class _Inflight:
    """Scheduler-side record of one admitted request."""
    future: ClusterFuture
    submitted: float                 # monotonic submit time
    deadline: Optional[float]        # absolute monotonic deadline (or None)
    priority: int


class AsyncClusterEngine:
    """Deadline-aware async front end over one :class:`LocalClusterEngine`.

    >>> sched = AsyncClusterEngine(graph, batch_slots=8, max_queue=64)
    >>> sched.serve_forever()
    >>> fut = sched.submit(ClusterRequest(seed=7), deadline_ms=50.0)
    >>> fut.add_done_callback(lambda f: print(f.result().conductance))
    >>> sched.shutdown()

    Parameters
    ----------
    engine_or_graph : an existing ``LocalClusterEngine`` to wrap, or a
        ``CSRGraph`` / ``GraphHandle`` (one is built with
        ``**engine_kwargs``; a sharded handle unlocks the ``dist`` pools,
        scheduled by the same EDF planner through the same tick-cost EMAs).
    max_queue : admission bound on unresolved requests (``QueueFull`` beyond).
    max_pools_per_tick : how many pools one tick steps, in EDF order.  None
        (default) steps every live pool — best throughput; 1 is strict EDF —
        tightest priority, what the EDF tests pin.
    telemetry : a shared :class:`MetricsRegistry`, or None to create one.
    default_deadline_ms : applied to requests that carry no deadline of
        their own (None = best-effort, no deadline).
    tracer : a :class:`~repro.serve.tracing.Tracer` to flight-record every
        request's span tree (installed on the wrapped engine too); None
        (default) inherits the engine's tracer, if any.  On deadline expiry
        the victim's span tree is dumped into ``telemetry`` as a bounded
        postmortem.  Tracing never changes answers (guarantee #8).
    cost_table : characterized tick costs seeding the EDF cost model before
        any EMA exists — a ``serve_bench --characterize`` artifact (path or
        dict; see :func:`~repro.serve.telemetry.load_cost_table`).  Without
        it, a cold pool is costed at ``_DEFAULT_TICK_COST`` until its first
        measured tick, which under-ranks slow pools exactly when deadlines
        are tightest (the first wave).  Measured EMAs always take over.
    """

    _DEFAULT_TICK_COST = 1e-3   # planner's cost guess before a pool's 1st EMA

    def __init__(self, engine_or_graph, *, max_queue: int = 256,
                 max_pools_per_tick: Optional[int] = None,
                 telemetry: Optional[MetricsRegistry] = None,
                 default_deadline_ms: Optional[float] = None,
                 tracer: Optional[Tracer] = None,
                 cost_table=None,
                 **engine_kwargs):
        if isinstance(engine_or_graph, LocalClusterEngine):
            if engine_kwargs:
                raise ValueError("engine_kwargs only apply when constructing "
                                 "the engine from a graph")
            self.engine = engine_or_graph
        elif isinstance(engine_or_graph,
                        (CSRGraph, GraphHandle, PartitionedCSR)):
            # any graph-like the engine itself accepts (as_handle coerces)
            self.engine = LocalClusterEngine(engine_or_graph, **engine_kwargs)
        else:
            raise TypeError(f"expected LocalClusterEngine or a graph-like "
                            f"(CSRGraph | GraphHandle | PartitionedCSR), got "
                            f"{type(engine_or_graph).__name__}")
        if max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.max_queue = max_queue
        self.max_pools_per_tick = max_pools_per_tick
        self.default_deadline_ms = default_deadline_ms
        self.telemetry = telemetry if telemetry is not None else \
            MetricsRegistry()
        if tracer is not None:
            self.engine.tracer = tracer     # one recorder for both layers
        self.tracer = tracer if tracer is not None else self.engine.tracer
        self.cost_table = load_cost_table(cost_table)
        self.last_plan: List[tuple] = []     # EDF order of the latest tick
        self._mutex = threading.Lock()       # admission queue + records
        self._engine_lock = threading.RLock()  # serializes engine access
        self._admissions: List[ClusterFuture] = []
        self._live: Dict[int, _Inflight] = {}   # ticket → record
        self._inflight = 0
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- submission (any thread) --------------------------------------------

    def submit(self, req: ClusterRequest,
               deadline_ms: Optional[float] = None,
               priority: Optional[int] = None) -> ClusterFuture:
        """Queue a request; returns its :class:`ClusterFuture` immediately.

        ``deadline_ms``/``priority`` override the request's own fields when
        given (the stored request is updated so the result reports the
        effective values).  Raises :class:`QueueFull` when ``max_queue``
        requests are already unresolved.

        A seed→result cache hit resolves the future *here*, on the caller's
        thread: no admission slot consumed, no lane occupied, no tick — the
        engine's cached converged answer (bit-identical to recomputing,
        guarantee #9) comes back before the drive loop ever sees the
        request.  Hits can therefore never be rejected by admission control
        and never miss a deadline.
        """
        updates = {}
        if deadline_ms is not None:
            updates["deadline_ms"] = deadline_ms
        if priority is not None:
            updates["priority"] = priority
        if req.deadline_ms is None and "deadline_ms" not in updates and \
                self.default_deadline_ms is not None:
            updates["deadline_ms"] = self.default_deadline_ms
        if updates:
            req = dataclasses.replace(req, **updates)
        # validate method/backend on the caller's thread, so a malformed
        # request raises here instead of stranding a future in the drive loop
        self.engine._pool_key(req, 0)
        fut = ClusterFuture(req)
        if self.tracer is not None:
            # trace opens on the caller's thread, *before* the future is
            # visible to the drive loop, so the queued phase can never miss
            # the admission that ends it
            fut.trace = self.tracer.request(
                seed=req.seed, method=req.method,
                deadline_ms=req.deadline_ms, priority=req.priority)
            fut.trace.phase("queued")
        # Result-cache probe (the cache and the version read are themselves
        # thread-safe, so no engine lock — a hit must not wait out a tick)
        hit = self.engine.cached_result(req)
        if hit is not None:
            self.telemetry.inc("scheduler/submitted")
            self.telemetry.inc("scheduler/cache_hits")
            latency_ms = (time.monotonic() - fut.submitted) * 1e3
            self.telemetry.observe("scheduler/request_latency",
                                   latency_ms / 1e3)
            self.telemetry.inc("scheduler/completed")
            if fut.trace is not None:
                fut.trace.resolve_cached(seed=req.seed)
            fut._resolve(hit, latency_ms)
            return fut
        with self._mutex:
            if self._inflight >= self.max_queue:
                self.telemetry.inc("scheduler/rejected")
                if fut.trace is not None:
                    fut.trace.finish("rejected")
                raise QueueFull(
                    f"{self._inflight} requests in flight (max_queue="
                    f"{self.max_queue}); back off and resubmit")
            self._inflight += 1
            self._admissions.append(fut)
        self.telemetry.inc("scheduler/submitted")
        self._wake.set()
        return fut

    def inflight(self) -> int:
        """Unresolved requests (admitted + live), the admission-bound gauge."""
        with self._mutex:
            return self._inflight

    # -- the tick (drive thread, or manual caller) --------------------------

    def tick(self) -> bool:
        """One scheduler iteration: admit → plan (EDF) → step pools in plan
        order → resolve completions → expire overdue requests.  Returns True
        if any engine pool progressed.  Safe to call from any thread (fully
        serialized); deterministic when driven single-threaded."""
        with self._engine_lock:
            admitted = self._admit()
            now = time.monotonic()
            plan = self._plan(now)
            self.last_plan = [key for key, _slack in plan]
            budget = (len(plan) if self.max_pools_per_tick is None
                      else self.max_pools_per_tick)
            progressed = False
            for key in self.last_plan[:budget]:
                dt = self.engine.tick_pool(key)
                if dt is None:
                    continue
                progressed = True
                label = pool_label(key)
                self.telemetry.observe(f"pool/{label}/tick_latency", dt)
                self.telemetry.ema(f"pool/{label}/tick_cost").update(dt)
            self._resolve_completed(time.monotonic())
            self._expire(time.monotonic())
            self._resolve_completed(time.monotonic())  # expiry harvests
            self._update_gauges()
            return progressed or admitted > 0

    def drain(self) -> None:
        """Block until every submitted request has resolved.  With the drive
        thread running this just waits; otherwise it ticks inline."""
        while self.inflight() > 0:
            if self._thread is not None and self._thread.is_alive():
                time.sleep(0.001)
            else:
                self.tick()

    # -- background drive mode ----------------------------------------------

    def serve_forever(self, idle_wait: float = 0.005) -> threading.Thread:
        """Start (idempotently) the daemon drive thread: ticks while there is
        work, parks on an event for ``idle_wait`` seconds when idle."""
        with self._mutex:
            if self._thread is not None and self._thread.is_alive():
                return self._thread
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._drive, args=(idle_wait,),
                name="AsyncClusterEngine", daemon=True)
            self._thread.start()
            return self._thread

    def _drive(self, idle_wait: float) -> None:
        while not self._stop.is_set():
            if not self.tick() and self.inflight() == 0:
                self._wake.wait(timeout=idle_wait)
                self._wake.clear()

    def shutdown(self, wait: bool = True) -> None:
        """Stop the drive thread.  ``wait=True`` (default) drains all
        in-flight work first; ``wait=False`` stops promptly and leaves
        unresolved futures pending."""
        if wait:
            self.drain()
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "AsyncClusterEngine":
        self.serve_forever()
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=exc == (None, None, None))

    # -- internals (all called under _engine_lock) --------------------------

    def _admit(self) -> int:
        with self._mutex:
            batch, self._admissions = self._admissions, []
        for fut in batch:
            ticket = self.engine.submit(fut.request, _trace=fut.trace)
            fut.ticket = ticket
            ddl = fut.request.deadline_ms
            # deadline and latency anchor at the submit() call, not at
            # admission: time spent waiting out a long tick counts
            self._live[ticket] = _Inflight(
                future=fut, submitted=fut.submitted,
                deadline=(None if ddl is None
                          else fut.submitted + ddl / 1000.0),
                priority=fut.request.priority)
        return len(batch)

    def _plan(self, now: float) -> List[tuple]:
        """EDF order over live pools: sort by slack = earliest resident
        deadline − now − estimated cost (tick-cost EMA read back from the
        telemetry registry × pending-ticks).  Pools with no deadlined
        residents sort after all deadlined ones, by descending priority then
        LRU position.  Returns [(pool_key, slack_or_None), …]."""
        entries = []
        for order, (key, pool) in enumerate(self.engine.live_pools()):
            deadlines = []
            priorities = []
            for ticket in pool.tickets():
                rec = self._live.get(ticket)
                if rec is None:
                    continue
                priorities.append(rec.priority)
                if rec.deadline is not None:
                    deadlines.append(rec.deadline)
            # cost estimate: the registry EMA is primary (fed by our ticks);
            # a fresh registry over a warm engine falls back to the pool's
            # own measurement, then to the characterized cost table, and
            # only then to the cold-start default
            ema = self.telemetry.ema_value(
                f"pool/{pool_label(key)}/tick_cost")
            if ema is None:
                ema = pool.cost_ema
            if ema is None:
                ema = lookup_cost(self.cost_table, key)
            cost = (ema if ema is not None else self._DEFAULT_TICK_COST) \
                * pool.pending_ticks()
            slack = (min(deadlines) - now - cost) if deadlines else None
            entries.append((key, slack,
                            max(priorities) if priorities else 0, order))
        entries.sort(key=lambda e: (e[1] is None,
                                    e[1] if e[1] is not None else 0.0,
                                    -e[2], e[3]))
        return [(key, slack) for key, slack, _p, _o in entries]

    def _resolve_completed(self, now: float) -> None:
        # pick up only the tickets this scheduler owns: results submitted to
        # a shared engine out-of-band stay claimable via engine.result()
        done = self.engine.take_completed(self._live.keys())
        for ticket, res in done.items():
            rec = self._live.pop(ticket)
            if (not res.deadline_missed and rec.deadline is not None
                    and now > rec.deadline):
                # finished naturally but late: deliver in full, flagged —
                # never silently late
                res.deadline_missed = True
            latency_ms = (now - rec.submitted) * 1e3
            self.telemetry.observe("scheduler/request_latency",
                                   latency_ms / 1e3)
            self.telemetry.inc("scheduler/completed")
            if res.deadline_missed:
                self.telemetry.inc("scheduler/deadline_missed")
                if rec.future.trace is not None:
                    # flight-record the victim: its full span tree goes into
                    # the telemetry snapshot as a bounded postmortem
                    rt = rec.future.trace
                    self.telemetry.add_postmortem(dict(
                        ticket=ticket, seed=res.request.seed,
                        method=res.request.method,
                        deadline_ms=res.request.deadline_ms,
                        latency_ms=latency_ms,
                        phases_ms=rt.summary()["phases_ms"],
                        tree=self.tracer.request_tree(rt.rid)))
            # resolve before releasing the admission slot: once inflight()
            # reads 0 (drain()'s condition), every future is already done
            rec.future._resolve(res, latency_ms)
            with self._mutex:
                self._inflight -= 1

    def _expire(self, now: float) -> None:
        overdue = [t for t, rec in self._live.items()
                   if rec.deadline is not None and now > rec.deadline]
        for ticket in overdue:
            self.engine.harvest_partial(ticket)

    def _update_gauges(self) -> None:
        tm = self.telemetry
        engine_queued = 0
        for key, pool in self.engine.pools.items():
            label = pool_label(key)
            tm.set_gauge(f"pool/{label}/occupancy", pool.occupancy())
            tm.set_gauge(f"pool/{label}/queued", len(pool.queue))
            engine_queued += len(pool.queue)
        with self._mutex:
            tm.set_gauge("scheduler/inflight", self._inflight)
            tm.set_gauge("scheduler/queue_depth",
                         engine_queued + len(self._admissions))
        for stat in ("promotions", "pools_evicted", "injections",
                     "completed", "partial_harvests", "steps",
                     "status_syncs", "aot_compiles", "aot_cache_hits",
                     "result_cache_hits", "result_cache_misses"):
            tm.set_gauge(f"engine/{stat}", self.engine.stats[stat])
