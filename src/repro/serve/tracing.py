"""Span-level request tracing: the serving stack's flight recorder.

Aggregate metrics lie in exactly the regime this system serves: work is
proportional to the *cluster*, not the graph, so per-request latency spans
several decades and a p99 histogram cannot say why any individual deadline
was missed — queue wait, EDF planning, tick cost, ladder promotion, or sweep.
This module is the attribution layer: a thread-safe, dependency-free
:class:`Tracer` with a bounded ring-buffer flight recorder that emits a span
tree per request across its full lifecycle

    submit → queued → admitted → injected → tick* → harvest → sweep
           → resolved | expired

plus pool-scoped ``tick`` spans (refill/step/harvest children, occupancy and
cost-EMA snapshots) and algorithm-level annotations threaded up from the
batched drivers (per-tick frontier sizes, push counts, capacity-ladder
bucket hops, overflow events, dist exchange volume — the paper-native work
measures).

Design rules (docs/algorithms.md, guarantee #8):

  * **Tracing never changes answers.**  Every call site only *reads* state
    the engine already computed (or host numpy the harvest already pulled);
    a traced stream is bit-identical to an untraced one, enforced by
    ``tests/test_tracing.py``.
  * **Disabled means free.**  Engines hold ``tracer=None`` by default and
    guard every site with one ``is not None`` check; the ambient
    :func:`annotate` hook used by the batched drivers early-exits on one
    attribute lookup when no tracer is active.  The no-op cost is measured
    in ``tests/test_tracing.py``.
  * **Bounded.**  Finished spans live in a ``deque(maxlen=capacity)`` ring;
    evictions are counted (``Tracer.dropped``), never silent.  Per-request
    *phase accounting* (:class:`RequestTrace`) is kept separately in O(1)
    per request so latency attribution survives ring eviction.

Exports: :meth:`Tracer.chrome_trace` renders Chrome trace-event JSON —
load the file in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``;
requests appear as one track per request id, pool ticks on track 0.
:meth:`Tracer.device_span` optionally wraps pool ticks in
``jax.profiler.TraceAnnotation`` so these host spans line up with device
traces captured by ``jax.profiler.trace``.

On deadline expiry the scheduler dumps the victim's span tree
(:meth:`Tracer.request_tree`) into the telemetry snapshot as a bounded
postmortem (`repro.serve.telemetry.MetricsRegistry.add_postmortem`).
"""
from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Span", "Tracer", "RequestTrace", "annotate", "current_scope",
           "TRACE_SCHEMA"]

TRACE_SCHEMA = "repro.serve.trace/v1"

_now = time.monotonic          # one clock for every span (and the scheduler)


class Span:
    """One timed interval (or instant event when ``t1 == t0``).

    ``sid`` is unique per tracer; ``parent`` nests spans; ``rid`` attaches
    the span to one request's tree (None = pool/driver scope).  ``attrs``
    are plain JSON-able values only.
    """

    __slots__ = ("sid", "parent", "rid", "name", "cat", "t0", "t1", "attrs")

    def __init__(self, sid: int, name: str, cat: str, t0: float,
                 parent: Optional[int], rid: Optional[int],
                 attrs: Dict[str, Any]):
        self.sid = sid
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1: Optional[float] = None
        self.parent = parent
        self.rid = rid
        self.attrs = attrs

    @property
    def duration_ms(self) -> Optional[float]:
        return None if self.t1 is None else (self.t1 - self.t0) * 1e3

    def to_dict(self) -> Dict[str, Any]:
        return dict(sid=self.sid, parent=self.parent, rid=self.rid,
                    name=self.name, cat=self.cat, t0_ms=self.t0 * 1e3,
                    dur_ms=self.duration_ms, attrs=dict(self.attrs))


# ------------------------------------------------------------ ambient scope
# The batched host drivers (core/batched*.py) annotate ladder dispatches
# without holding a tracer reference: the engine (or any caller) pushes an
# active (tracer, parent span, rid) scope onto this thread-local stack and
# annotate() attaches events under it.  No scope → one attribute lookup and
# return, which is what keeps a disabled tracer near-free.

_scope = threading.local()


def current_scope():
    """(tracer, parent_sid, rid) of the innermost active scope, or None."""
    stack = getattr(_scope, "stack", None)
    return stack[-1] if stack else None


def annotate(name: str, **attrs) -> None:
    """Attach an instant event under the active trace scope (no-op without
    one).  This is the hook the batched drivers use for the paper-native
    work measures: ladder bucket hops, overflow events, per-tick frontier
    and push counts, dist exchange volume."""
    stack = getattr(_scope, "stack", None)
    if not stack:
        return
    tracer, parent, rid = stack[-1]
    tracer.event(name, cat="annotation", parent=parent, rid=rid, **attrs)


class Tracer:
    """Thread-safe bounded flight recorder of :class:`Span` records.

    ``capacity`` bounds the *finished*-span ring; evicted spans increment
    ``dropped``.  ``device_annotations=True`` makes :meth:`device_span`
    emit ``jax.profiler.TraceAnnotation`` scopes (host spans then line up
    with device traces); off by default so the tracer stays import-free of
    jax.
    """

    def __init__(self, capacity: int = 8192,
                 device_annotations: bool = False):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.device_annotations = device_annotations
        self.dropped = 0
        self._lock = threading.Lock()
        self._ring: deque = deque(maxlen=capacity)   # finished spans
        self._open: Dict[int, Span] = {}
        self._next_sid = 0
        self._next_rid = 0
        self._epoch = _now()     # t=0 of every exported timestamp

    # -- span primitives -----------------------------------------------------

    def begin(self, name: str, cat: str = "span", *,
              parent: Optional[int] = None, rid: Optional[int] = None,
              t0: Optional[float] = None, **attrs) -> int:
        """Open a span; returns its sid (pass to :meth:`end`)."""
        t0 = _now() if t0 is None else t0
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
            self._open[sid] = Span(sid, name, cat, t0, parent, rid, attrs)
        return sid

    def end(self, sid: int, t1: Optional[float] = None, **attrs) -> None:
        """Close an open span and move it into the ring (unknown/already
        closed sids are ignored — a ring this size never blocks serving)."""
        t1 = _now() if t1 is None else t1
        with self._lock:
            span = self._open.pop(sid, None)
            if span is None:
                return
            span.t1 = t1
            if attrs:
                span.attrs.update(attrs)
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(span)

    def event(self, name: str, cat: str = "event", *,
              parent: Optional[int] = None, rid: Optional[int] = None,
              **attrs) -> None:
        """Record an instant event (a zero-duration span)."""
        t = _now()
        with self._lock:
            span = Span(self._next_sid, name, cat, t, parent, rid, attrs)
            self._next_sid += 1
            span.t1 = t
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(span)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "span", *,
             parent: Optional[int] = None, rid: Optional[int] = None,
             **attrs):
        """``with tracer.span("step"): ...`` — begin/end around a block;
        yields the sid so children can nest under it."""
        sid = self.begin(name, cat, parent=parent, rid=rid, **attrs)
        try:
            yield sid
        finally:
            self.end(sid)

    @contextlib.contextmanager
    def scope(self, parent: Optional[int] = None,
              rid: Optional[int] = None):
        """Activate this tracer for ambient :func:`annotate` calls made
        anywhere below this frame (the engine wraps each pool tick so the
        batched layers' annotations land under the tick span)."""
        stack = getattr(_scope, "stack", None)
        if stack is None:
            stack = _scope.stack = []
        stack.append((self, parent, rid))
        try:
            yield
        finally:
            stack.pop()

    def device_span(self, name: str):
        """A ``jax.profiler.TraceAnnotation`` scope when device annotations
        are enabled (and jax provides one), else a null context.  Lets the
        host-side tick spans line up with device traces in Perfetto."""
        if not self.device_annotations:
            return contextlib.nullcontext()
        try:
            from jax.profiler import TraceAnnotation
        except Exception:       # pragma: no cover - jax without profiler
            return contextlib.nullcontext()
        return TraceAnnotation(name)

    # -- request lifecycle ---------------------------------------------------

    def request(self, **attrs) -> "RequestTrace":
        """Open a request-root span and return its :class:`RequestTrace`
        handle (the engine/scheduler drive its phase transitions)."""
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
        root = self.begin("request", cat="request", rid=rid, **attrs)
        return RequestTrace(self, rid, root)

    # -- read side -----------------------------------------------------------

    def spans(self, rid: Optional[int] = None,
              include_open: bool = True) -> List[Span]:
        """Snapshot of recorded spans, oldest first (optionally one
        request's), finished ring plus still-open spans."""
        with self._lock:
            out = list(self._ring)
            if include_open:
                out.extend(self._open.values())
        out.sort(key=lambda s: (s.t0, s.sid))
        if rid is not None:
            out = [s for s in out if s.rid == rid]
        return out

    def request_tree(self, rid: int, max_spans: int = 256) -> Dict[str, Any]:
        """The request's span tree as a nested JSON-able dict — the
        postmortem payload dumped into the telemetry snapshot on a deadline
        miss.  Bounded: at most ``max_spans`` nodes (oldest kept, the
        lifecycle phases; a ``truncated`` count reports the rest)."""
        spans = self.spans(rid=rid)
        truncated = max(0, len(spans) - max_spans)
        spans = spans[:max_spans]
        nodes = {s.sid: dict(s.to_dict(), children=[]) for s in spans}
        roots = []
        for s in spans:
            node = nodes[s.sid]
            if s.parent in nodes:
                nodes[s.parent]["children"].append(node)
            else:
                roots.append(node)
        return dict(schema=TRACE_SCHEMA, rid=rid, spans=len(spans),
                    truncated=truncated, dropped_ring_total=self.dropped,
                    tree=roots)

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> List[Dict[str, Any]]:
        """Chrome trace-event list (Perfetto/chrome://tracing loadable):
        complete events (ph "X") for spans, instants (ph "i") for events;
        one tid per request, tid 0 for pool/driver scope."""
        events: List[Dict[str, Any]] = []
        for s in self.spans():
            tid = 0 if s.rid is None else s.rid + 1
            ts = (s.t0 - self._epoch) * 1e6
            args = dict(s.attrs)
            if s.rid is not None:
                args["rid"] = s.rid
            base = dict(name=s.name, cat=s.cat, pid=0, tid=tid, ts=ts,
                        args=args)
            if s.t1 is None or s.t1 == s.t0:
                events.append(dict(base, ph="i", s="t"))
            else:
                events.append(dict(base, ph="X",
                                   dur=(s.t1 - s.t0) * 1e6))
        return events


class RequestTrace:
    """Drives one request's contiguous phase spans under its root span.

    Every :meth:`phase` call closes the open phase *at the same timestamp*
    the next one opens, so the phases tile [submit, resolve] with no gaps by
    construction — attribution coverage is then a measurement of how much
    of the resolved latency the recorded phases explain, not an artifact of
    instrumentation holes.  Phase durations are also accumulated into
    ``phase_ms`` (O(#phases) per request), so latency attribution survives
    ring-buffer eviction of the underlying spans.
    """

    __slots__ = ("tracer", "rid", "root", "t0", "t1", "phase_ms", "status",
                 "_phase_sid", "_phase_name", "_phase_t0", "_lock")

    def __init__(self, tracer: Tracer, rid: int, root: int):
        self.tracer = tracer
        self.rid = rid
        self.root = root
        self.t0 = _now()
        self.t1: Optional[float] = None
        self.phase_ms: Dict[str, float] = {}
        self.status: Optional[str] = None
        self._phase_sid: Optional[int] = None
        self._phase_name: Optional[str] = None
        self._phase_t0 = self.t0
        self._lock = threading.Lock()

    def _close_phase(self, t: float) -> None:
        if self._phase_sid is not None:
            self.tracer.end(self._phase_sid, t1=t)
            dt = (t - self._phase_t0) * 1e3
            name = self._phase_name
            self.phase_ms[name] = self.phase_ms.get(name, 0.0) + dt
            self._phase_sid = None

    def phase(self, name: str, **attrs) -> None:
        """Transition to phase ``name``: the previous phase ends and the new
        one begins at one shared timestamp."""
        t = _now()
        with self._lock:
            if self.t1 is not None:      # finished requests stay finished
                return
            self._close_phase(t)
            self._phase_sid = self.tracer.begin(
                name, cat="phase", parent=self.root, rid=self.rid, t0=t,
                **attrs)
            self._phase_name = name
            self._phase_t0 = t

    def event(self, name: str, **attrs) -> None:
        """Instant lifecycle event under the current phase (or the root)."""
        with self._lock:
            parent = (self._phase_sid if self._phase_sid is not None
                      else self.root)
        self.tracer.event(name, cat="lifecycle", parent=parent,
                          rid=self.rid, **attrs)

    def finish(self, status: str = "resolved", **attrs) -> None:
        """Close the open phase and the root span (idempotent)."""
        t = _now()
        with self._lock:
            if self.t1 is not None:
                return
            self._close_phase(t)
            self.t1 = t
            self.status = status
        self.tracer.end(self.root, t1=t, status=status, **attrs)

    def resolve_cached(self, **attrs) -> None:
        """Terminal sequence for a result-cache hit: a ``cache_hit``
        lifecycle event, a (zero-ish width) ``deliver`` phase, and a
        resolved finish — the flight-recorder shape of a request that never
        touched a lane (serve/result_cache.py).  ``attrs`` (cache key
        context, seed, …) land on both the event and the phase."""
        self.event("cache_hit", **attrs)
        self.phase("deliver", cached=True, **attrs)
        self.finish("resolved")

    # -- attribution ---------------------------------------------------------

    @property
    def latency_ms(self) -> Optional[float]:
        return None if self.t1 is None else (self.t1 - self.t0) * 1e3

    def coverage(self) -> Optional[float]:
        """Fraction of the root span's wall time the recorded phases
        account for (the attribution-gap acceptance gate reads this);
        None until finished."""
        if self.t1 is None:
            return None
        total = (self.t1 - self.t0) * 1e3
        if total <= 0.0:
            return 1.0
        return min(1.0, sum(self.phase_ms.values()) / total)

    def summary(self) -> Dict[str, Any]:
        """JSON-able per-request attribution record (the BENCH_trace.json
        ``requests`` section)."""
        return dict(rid=self.rid, latency_ms=self.latency_ms,
                    status=self.status, coverage=self.coverage(),
                    phases_ms={k: round(v, 6)
                               for k, v in self.phase_ms.items()})
