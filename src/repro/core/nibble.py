"""Parallel Nibble (paper §4.2, Figure 1) — truncated lazy random walk.

Frontier-synchronous rounds: each round sends half of every frontier vertex's
mass to itself (VERTEXMAP) and half split evenly over its neighbors (EDGEMAP),
then the new frontier is ``{v : p'[v] ≥ d(v)·ε}``.  If the new frontier is
empty the *previous* vector is returned (paper lines 15–16).  Truncation is
implicit: only frontier mass survives into ``p'`` (a fresh sparse set each
round in the paper; a fresh dense vector here — see DESIGN.md §2 note on
dense-state backends).

Work O(T/ε), depth O(T log(1/ε))  (Theorem 2).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .frontier import (Frontier, expand, pack_unique, singleton,
                       scatter_add_dense, one_hot_f32)

__all__ = ["NibbleResult", "nibble", "nibble_fixedcap"]


class NibbleResult(NamedTuple):
    p: jnp.ndarray          # f32[n] — diffusion vector for the sweep cut
    iterations: jnp.ndarray  # int32
    pushes: jnp.ndarray      # int32 — total vertex pushes (work counter)
    edge_work: jnp.ndarray   # int32 — total edges traversed
    overflow: jnp.ndarray    # bool


class _State(NamedTuple):
    p: jnp.ndarray
    frontier: Frontier
    t: jnp.ndarray
    pushes: jnp.ndarray
    edge_work: jnp.ndarray
    done: jnp.ndarray
    overflow: jnp.ndarray


@functools.partial(jax.jit, static_argnums=(4, 5))
def nibble_fixedcap(graph: CSRGraph, x, eps, T,
                    cap_f: int, cap_e: int) -> NibbleResult:
    """One capacity bucket of parallel Nibble (jit-compiled per (cap_f, cap_e))."""
    n = graph.n
    deg = graph.deg

    def cond(s: _State):
        return (~s.done) & (~s.overflow) & (s.t < T)

    def body(s: _State) -> _State:
        f = s.frontier
        fvalid = f.valid()
        fids = jnp.where(fvalid, f.ids, n)
        safe = jnp.minimum(fids, n - 1)
        pf = jnp.where(fvalid, s.p[safe], 0.0)
        dv = jnp.maximum(deg[safe], 1)

        # VERTEXMAP: p'[v] = p[v]/2   (fresh p' each round — truncation)
        p_new = jnp.zeros_like(s.p)
        p_new = scatter_add_dense(p_new, fids, pf * 0.5, fvalid)

        # EDGEMAP: p'[w] += p[v] / (2 d(v)) for every (v, w)
        eb = expand(graph, f, cap_e)
        contrib = pf[eb.slot] / (2.0 * dv[eb.slot])
        p_new = scatter_add_dense(p_new, eb.dst, contrib, eb.valid)

        # new frontier = {v in F ∪ N(F) : p'[v] ≥ d(v) ε}
        cands = jnp.concatenate([fids, eb.dst])
        cvalid = jnp.concatenate([fvalid, eb.valid])
        csafe = jnp.minimum(cands, n - 1)
        keep = cvalid & (deg[csafe] > 0) & (p_new[csafe] >= deg[csafe] * eps)
        nf = pack_unique(cands, keep, n, cap_f)

        empty = nf.count == 0
        return _State(
            p=jnp.where(empty, s.p, p_new),     # return p_{i-1} on empty
            frontier=nf,
            t=s.t + 1,
            pushes=s.pushes + f.count,
            edge_work=s.edge_work + eb.total,
            done=empty,
            overflow=s.overflow | nf.overflow | eb.overflow,
        )

    p0 = one_hot_f32(x, n)
    s0 = _State(p=p0, frontier=singleton(x, n, cap_f),
                t=jnp.asarray(0, jnp.int32), pushes=jnp.asarray(0, jnp.int32),
                edge_work=jnp.asarray(0, jnp.int32), done=jnp.asarray(False),
                overflow=jnp.asarray(False))
    s = jax.lax.while_loop(cond, body, s0)
    return NibbleResult(p=s.p, iterations=s.t, pushes=s.pushes,
                        edge_work=s.edge_work, overflow=s.overflow)


def nibble(graph: CSRGraph, x, eps: float = 1e-8, T: int = 20,
           cap_f: int = 1 << 12, cap_e: int = 1 << 16,
           max_cap_e: int = 1 << 26) -> NibbleResult:
    """Bucketed driver: retry with doubled capacities on overflow."""
    while True:
        out = nibble_fixedcap(graph, x, eps, T, cap_f, cap_e)
        if not bool(out.overflow) or cap_e >= max_cap_e:
            return out
        cap_f = min(cap_f * 2, graph.n + 1)
        cap_e = cap_e * 2
