"""phi3-mini-3.8b — RoPE SwiGLU GQA [arXiv:2404.14219; unverified].
32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-mini-3.8b",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    layer_pattern=("attn",),
    source="arXiv:2404.14219 (unverified)",
)
