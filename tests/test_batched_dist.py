"""Sharded batched engine tests (ISSUE 5): bit-identity of the dist path,
dist lane pools in the serving engine, and the partition padding guard.

The 8-host-device runs execute in subprocesses (marker ``dist``) so the main
test process keeps its single-device jax config — the same recipe as
tests/test_distributed.py, but part of tier-1 (the marker is *not* excluded
by the default ``-m`` filter) and re-run standalone by the CI dist-smoke job.
"""
import numpy as np
import pytest

from repro.graphs import (GraphHandle, as_handle, build_csr, degree_reorder,
                          partition_rows, rand_local, sbm)
from repro.core import pr_nibble, sweep_cut_dense
from repro.core.batched_sparse import pick_backend
from repro.serve.telemetry import pool_label
from conftest import run_subprocess_json as _run_sub


_PRELUDE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.graphs import sbm, rand_local, GraphHandle
mesh = make_host_mesh()
out = {}
"""


# --------------------------------------------------------- bit-identity (dist)

_BITIDENT_SCRIPT = _PRELUDE + r"""
from repro.core.batched import batched_pr_nibble
from repro.core.batched_dist import batched_dist_pr_nibble

for name, g in [("sbm", sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)),
                ("randLocal", rand_local(1003, degree=5, seed=3))]:
    h = GraphHandle.shard(g, mesh)
    rng = np.random.default_rng(0)
    seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                       size=6).astype(np.int32)
    eps = np.array([1e-5, 1e-6, 1e-5, 1e-6, 1e-5, 1e-6], np.float32)
    alpha = np.array([0.05, 0.01, 0.01, 0.05, 0.02, 0.03], np.float32)
    ref = batched_pr_nibble(g, seeds, eps, alpha)
    got = batched_dist_pr_nibble(h, seeds, eps, alpha,
                                 cap_f=256, cap_e=4096, cap_x=1024)
    out[name] = dict(
        p_bitident=bool((got.p == ref.p).all()),
        r_bitident=bool((got.r == ref.r).all()),
        iters=bool((got.iterations == ref.iterations).all()),
        pushes=bool((got.pushes == ref.pushes).all()),
        edge_work=bool((got.edge_work == ref.edge_work).all()),
        overflow=bool(got.overflow.any()),
        exchanged_pos=bool((got.exchanged > 0).all()),
        buckets=len(got.buckets),
    )

# bucket-overflow -> ladder-promotion: start the dist ladder at deliberately
# tiny caps so the first bucket overflows, and require the promoted rerun to
# still be bit-identical to the dense driver
g = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
h = GraphHandle.shard(g, mesh)
rng = np.random.default_rng(1)
seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                   size=4).astype(np.int32)
ref = batched_pr_nibble(g, seeds, 1e-6, 0.05)
got = batched_dist_pr_nibble(h, seeds, 1e-6, 0.05,
                             cap_f=8, cap_e=64, cap_x=16)
out["ladder"] = dict(
    buckets=len(got.buckets),
    p_bitident=bool((got.p == ref.p).all()),
    r_bitident=bool((got.r == ref.r).all()),
    counters=bool((got.iterations == ref.iterations).all()
                  and (got.pushes == ref.pushes).all()),
    overflow=bool(got.overflow.any()),
)
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.dist
def test_batched_dist_bit_identity():
    out = _run_sub(_BITIDENT_SCRIPT)
    for name in ("sbm", "randLocal"):
        res = out[name]
        assert res["p_bitident"] and res["r_bitident"], res
        assert res["iters"] and res["pushes"] and res["edge_work"], res
        assert not res["overflow"]
        assert res["exchanged_pos"]   # the exchange counter must observe work
    lad = out["ladder"]
    assert lad["buckets"] > 1        # the tiny first bucket had to promote
    assert lad["p_bitident"] and lad["r_bitident"] and lad["counters"], lad
    assert not lad["overflow"]


# ------------------------------------------------- engine dist pools + mixing

_ENGINE_SCRIPT = _PRELUDE + r"""
from repro.serve import ClusterRequest, LocalClusterEngine
from repro.serve.scheduler import AsyncClusterEngine
from repro.serve.telemetry import pool_label

g = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
h = GraphHandle.shard(g, mesh)
rng = np.random.default_rng(0)
seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                   size=12).astype(np.int32)
caps = dict(cap_f=256, cap_e=1 << 13, cap_n=1 << 10, sweep_cap_e=1 << 14,
            cap_x=1 << 11, cap_v=256)
reqs = [ClusterRequest(seed=int(s), alpha=0.05, eps=1e-5,
                       backend=["dense", "sparse", "dist", None][i % 4])
        for i, s in enumerate(seeds)]

eng_ref = LocalClusterEngine(g, batch_slots=4, backend="dense",
                             **{k: v for k, v in caps.items() if k != "cap_x"})
ref = eng_ref.run([ClusterRequest(seed=r.seed, alpha=r.alpha, eps=r.eps)
                   for r in reqs])

# mixed dense/sparse/dist stream through the async scheduler, manual ticks
sched = AsyncClusterEngine(LocalClusterEngine(h, batch_slots=4, **caps),
                           max_queue=64)
futs = [sched.submit(r) for r in reqs]
while sched.inflight():
    sched.tick()
res = [f.result() for f in futs]

out["answers_match"] = all(
    a.conductance == b.conductance and a.size == b.size
    and a.pushes == b.pushes and a.iterations == b.iterations
    and (np.sort(a.cluster) == np.sort(b.cluster)).all()
    for a, b in zip(res, ref))
out["served_backends"] = sorted({r.backend for r in res})
labels = [pool_label(k) for k, _ in sched.engine.pools.items()]
out["dist_labels"] = sorted(l for l in labels if "dist" in l)
out["dist_pool_served"] = sum(r.backend == "dist" for r in res)

# dist pools must be schedulable observables like any other pool (clear the
# result cache first: the mixed stream already answered this seed, and dist
# shares the dense cache family, so a hit would resolve without any lane)
eng2 = sched.engine
eng2.result_cache.invalidate()
req = ClusterRequest(seed=int(seeds[0]), alpha=0.05, eps=1e-5, backend="dist")
t = eng2.submit(req)
key = eng2._pool_key(req, 0)
pool = eng2.pools[key]
pool.refill()
out["pending_rounds_pos"] = bool(pool.pending_rounds().max() >= 1)
out["pending_ticks_pos"] = pool.pending_ticks() >= 1
eng2.drain()
out["late_result_ok"] = eng2.result(t).conductance == ref[0].conductance \
    if reqs[0].seed == req.seed else True
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.dist
def test_engine_dist_pools_mixed_stream():
    out = _run_sub(_ENGINE_SCRIPT)
    assert out["answers_match"]
    assert out["served_backends"] == ["dense", "dist", "sparse"]
    assert out["dist_pool_served"] == 3
    # dist pools must be distinguishable in telemetry labels (shard count)
    assert out["dist_labels"] and all("dist@data8" in l
                                      for l in out["dist_labels"])
    assert out["pending_rounds_pos"] and out["pending_ticks_pos"]
    assert out["late_result_ok"]


# ----------------------------------------------- partition padding guard

_PADDING_SCRIPT = _PRELUDE + r"""
from repro.core.batched_dist import batched_dist_pr_nibble

# 1003 vertices over 8 shards -> rows_per=126, 5 padded sentinel vertices
g = rand_local(1003, degree=5, seed=3)
h = GraphHandle.shard(g, mesh)
pg = h.partitioned()
out["n_true"] = pg.n_true
out["n_pad"] = pg.n
out["num_padded"] = pg.num_padded
deg = np.asarray(pg.deg).reshape(-1)
out["padded_deg_zero"] = bool((deg[pg.n_true:] == 0).all())

seeds = np.array([3, 500, 999, 1002], np.int32)  # 1002 in the padded shard
got = batched_dist_pr_nibble(h, seeds[:3], 1e-6, 0.05,
                             cap_f=256, cap_e=8192, cap_x=2048)
# sliced outputs: padding never escapes the driver
out["p_shape"] = list(got.p.shape)
# a frontier can never contain a padded vertex: run with the raw kernel and
# check no mass ever lands beyond n_true (p/r of padded rows must stay 0;
# the driver's slice would hide it, so check support sums match full mass)
out["mass_ok"] = bool(np.allclose(got.p.sum(axis=1) + got.r.sum(axis=1),
                                  1.0, atol=1e-4))

# multi-host NCP: the dist profile must equal the dense profile exactly
# (bit-identical diffusions -> identical sweep curves -> identical minima)
from repro.core.ncp import ncp
kw = dict(num_seeds=8, alphas=(0.05,), epss=(1e-5,), batch=4,
          cap_f=256, cap_e=8192, cap_n=512, sweep_cap_e=1 << 14)
prof_dense = ncp(g, backend="dense", **kw)
prof_dist = ncp(h, backend="dist", **kw)
out["ncp_runs"] = [prof_dense.num_runs, prof_dist.num_runs]
out["ncp_match"] = bool(
    (prof_dense.best_conductance == prof_dist.best_conductance).all())
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.dist
def test_partition_padding_guard():
    out = _run_sub(_PADDING_SCRIPT)
    assert out["n_true"] == 1003
    assert out["n_pad"] == 8 * 126
    assert out["num_padded"] == out["n_pad"] - out["n_true"]
    assert out["padded_deg_zero"]       # degree-0 guard
    assert out["p_shape"] == [3, 1003]  # sliced to n_true
    # all diffusion mass is accounted for inside the true vertex range —
    # nothing ever leaked into (or out through) a padded sentinel vertex
    assert out["mass_ok"]
    # ncp(backend="dist") reproduces the dense profile exactly
    assert out["ncp_runs"][0] == out["ncp_runs"][1]
    assert out["ncp_match"]


# --------------------------------------------- host-side (single device) tests

def test_partition_rows_records_true_n(local_graph):
    # 2000 over 7 shards: rows_per=286 -> 2 padded sentinel vertices
    pg = partition_rows(local_graph, 7)
    assert pg.n_true == local_graph.n
    assert pg.n == pg.rows_per * 7
    assert pg.num_padded == pg.n - local_graph.n > 0
    deg = np.asarray(pg.deg).reshape(-1)
    assert (deg[pg.n_true:] == 0).all()
    # the indices pad value is out of range of every real vertex
    idx = np.asarray(pg.indices)
    assert idx.max() <= pg.n


def test_partition_rejects_edges_into_padding():
    # a malformed CSR whose last shard's slab targets a would-be padded
    # vertex must be rejected, not silently routed
    g = build_csr(np.array([[0, 1], [1, 2], [2, 3], [3, 4]]), 5)
    import dataclasses
    bad = dataclasses.replace(g, n=4)   # n lies: vertex 4 is now "padding"
    with pytest.raises(ValueError):
        partition_rows(bad, 3)


def test_graph_handle_gather_roundtrip(sbm_graph):
    pg = partition_rows(sbm_graph, 8)
    h = GraphHandle.from_partitioned(pg)
    g2 = h.local()
    assert g2.n == sbm_graph.n and g2.m == sbm_graph.m
    assert (np.asarray(g2.indptr) == np.asarray(sbm_graph.indptr)).all()
    assert (np.asarray(g2.indices) == np.asarray(sbm_graph.indices)).all()
    assert (np.asarray(g2.deg) == np.asarray(sbm_graph.deg)).all()
    # degrees() answers without a resident CSR
    h2 = GraphHandle.from_partitioned(partition_rows(sbm_graph, 8))
    assert (h2.degrees() == np.asarray(sbm_graph.deg)).all()


def test_as_handle_coercions(sbm_graph):
    h = as_handle(sbm_graph)
    assert h.kind == "local" and not h.is_sharded and h.n == sbm_graph.n
    assert as_handle(h) is h
    pg = partition_rows(sbm_graph, 4)
    hp = as_handle(pg)
    assert hp.is_sharded and hp.num_shards == 4 and hp.n == sbm_graph.n
    with pytest.raises(ValueError):
        hp.require_mesh()
    with pytest.raises(TypeError):
        as_handle(42)


def test_degree_reorder_preserves_clustering(sbm_graph):
    """The degree_reorder hook: clustering a relabeled graph from the
    relabeled seed gives the same diffusion (up to the permutation) and the
    same best cut."""
    g2, perm = degree_reorder(sbm_graph)
    deg2 = np.asarray(g2.deg)
    assert (np.diff(deg2) <= 0).all()   # heavy rows first, monotonically
    seed = 5
    ref = pr_nibble(sbm_graph, seed, eps=1e-6, alpha=0.05)
    got = pr_nibble(g2, int(perm[seed]), eps=1e-6, alpha=0.05)
    p_ref = np.asarray(ref.p)
    p_got = np.asarray(got.p)
    assert int(got.pushes) == int(ref.pushes)
    assert int(got.iterations) == int(ref.iterations)
    assert np.allclose(p_got[perm], p_ref, atol=1e-7)
    sw_ref = sweep_cut_dense(sbm_graph, ref.p, 1 << 10, 1 << 14)
    sw_got = sweep_cut_dense(g2, got.p, 1 << 10, 1 << 14)
    assert int(sw_got.best_size) == int(sw_ref.best_size)
    members_ref = np.sort(np.asarray(sw_ref.order)[: int(sw_ref.best_size)])
    members_got = np.sort(perm.argsort()[
        np.asarray(sw_got.order)[: int(sw_got.best_size)]])
    assert (members_got == members_ref).all()


def test_ops_graph_seam(sbm_graph):
    """The op-layer graph seam: degrees/expansion answer for any graph-like,
    and a sharded-only graph refuses local expansion instead of silently
    gathering."""
    from repro.core import ops
    from repro.core.frontier import singleton, expand

    f = singleton(5, sbm_graph.n, 64)
    eb_ref = expand(sbm_graph, f, 256)
    eb = ops.graph_expand(as_handle(sbm_graph), f, 256)
    assert (np.asarray(eb.dst) == np.asarray(eb_ref.dst)).all()
    assert int(eb.total) == int(eb_ref.total)

    pg = partition_rows(sbm_graph, 4)
    assert (ops.graph_degrees(pg) == np.asarray(sbm_graph.deg)).all()
    # bare PartitionedCSR and sharded-only handle both refuse local expansion
    with pytest.raises(ValueError, match="sharded-only"):
        ops.graph_expand(pg, f, 256)
    with pytest.raises(ValueError, match="sharded-only"):
        ops.graph_expand(GraphHandle.from_partitioned(pg), f, 256)
    # a sharded handle that kept its source CSR expands fine
    h = GraphHandle.from_partitioned(pg, csr=sbm_graph)
    eb2 = ops.graph_expand(h, f, 256)
    assert (np.asarray(eb2.dst) == np.asarray(eb_ref.dst)).all()


def test_pick_backend_dist_heuristic():
    # unchanged local behavior
    assert pick_backend(100, 64) == "dense"
    assert pick_backend(100_000, 64) == "sparse"
    # sharded but no budget: never forces dist
    assert pick_backend(100_000, 64, num_shards=8) == "sparse"
    # sharded + the dense lane state blows the chip budget: dist
    assert pick_backend(100_000, 64, num_shards=8,
                        chip_budget=100_000) == "dist"
    # fits on chip: local heuristic applies
    assert pick_backend(100, 64, num_shards=8,
                        chip_budget=1 << 30) == "dense"


def test_pool_label_encodes_topology():
    key5 = ("pr_nibble", "dense", (True, 1.0), "xla", 0)
    assert pool_label(key5) == "pr_nibble:dense:xla:(True, 1.0):b0"
    key6 = ("pr_nibble", "dense", (True, 1.0), "xla", 0, None)
    assert pool_label(key6) == pool_label(key5)
    kd = ("pr_nibble", "dist", (True, 1.0), "xla", 2, ("data", 8))
    assert pool_label(kd) == "pr_nibble:dist@data8:xla:(True, 1.0):b2"
    # distinct topologies must produce distinct labels (no EMA aliasing)
    kd2 = ("pr_nibble", "dist", (True, 1.0), "xla", 2, ("data", 4))
    assert pool_label(kd) != pool_label(kd2)


def test_dist_requests_rejected_on_local_engine(sbm_graph):
    from repro.serve import ClusterRequest, LocalClusterEngine
    eng = LocalClusterEngine(sbm_graph, batch_slots=2)
    with pytest.raises(ValueError):
        eng.submit(ClusterRequest(seed=1, backend="dist"))
    with pytest.raises(ValueError):
        LocalClusterEngine(sbm_graph, backend="dist")
