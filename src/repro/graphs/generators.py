"""Synthetic graph families.

The paper evaluates on SNAP social graphs plus two synthetic families
(``randLocal`` and ``3D-grid``).  The SNAP graphs (up to 6.4B edges) cannot be
shipped inside this container, so the experiment harness reproduces every
qualitative claim on the two synthetic families from the paper *exactly as
described*, plus RMAT (power-law, stands in for the social graphs) and SBM
planted-partition graphs (ground-truth low-conductance clusters, used to
validate cluster recovery).  ``load_edge_file`` in :mod:`repro.graphs.csr`
accepts the real SNAP edge lists unmodified for cluster deployments.
"""
from __future__ import annotations

import numpy as np

from .csr import CSRGraph, build_csr

__all__ = ["rand_local", "grid3d", "rmat", "sbm", "ba", "make_graph"]


def rand_local(n: int, degree: int = 5, seed: int = 0) -> CSRGraph:
    """PBBS-style random local graph (paper §5: "every vertex has five edges
    to neighbors chosen with probability proportional to the difference in the
    neighbor's ID value from the vertex's ID").

    Following the PBBS generator the decay is *inverse* in ID distance (so
    nearby IDs are likely neighbors and local clusters exist): neighbor of v
    is ``v ± d`` with ``P(d) ∝ 1/d``.
    """
    rng = np.random.default_rng(seed)
    # inverse-distance sampling via d = floor(exp(U * ln(n/2)))
    u = rng.random((n, degree))
    d = np.floor(np.exp(u * np.log(max(n // 2, 2)))).astype(np.int64)
    d = np.maximum(d, 1)
    sign = rng.integers(0, 2, size=(n, degree)) * 2 - 1
    src = np.repeat(np.arange(n, dtype=np.int64), degree)
    dst = (src + (sign * d).reshape(-1)) % n
    return build_csr(np.stack([src, dst], axis=1), n)


def grid3d(side: int, torus: bool = False) -> CSRGraph:
    """3D grid: every vertex has 6 edges, 2 per dimension (paper §5)."""
    n = side ** 3
    coords = np.arange(n, dtype=np.int64)
    x = coords % side
    y = (coords // side) % side
    z = coords // (side * side)
    edges = []
    for (dx, dy, dz) in [(1, 0, 0), (0, 1, 0), (0, 0, 1)]:
        nx_, ny_, nz_ = x + dx, y + dy, z + dz
        if torus:
            nx_, ny_, nz_ = nx_ % side, ny_ % side, nz_ % side
            ok = np.ones(n, dtype=bool)
        else:
            ok = (nx_ < side) & (ny_ < side) & (nz_ < side)
        nid = nx_ + ny_ * side + nz_ * side * side
        edges.append(np.stack([coords[ok], nid[ok]], axis=1))
    return build_csr(np.concatenate(edges, axis=0), n)


def rmat(scale: int, edge_factor: int = 8, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> CSRGraph:
    """RMAT power-law graph (Graph500 parameters by default).

    Stand-in for the paper's social graphs: heavy-tailed degrees, small
    low-conductance communities.
    """
    n = 1 << scale
    e = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(e)
        # quadrant probabilities a, b, c, d
        go_right = r > a + b          # dst high bit
        go_down = ((r > a) & (r <= a + b)) | (r > a + b + c)  # src high bit
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    # permute vertex ids so degree is not correlated with id
    perm = rng.permutation(n)
    return build_csr(np.stack([perm[src], perm[dst]], axis=1), n)


def sbm(k: int, size: int, p_in: float, p_out: float, seed: int = 0) -> CSRGraph:
    """Stochastic block model with ``k`` planted clusters of ``size`` vertices.

    Ground-truth clusters have expected conductance
    ``≈ p_out(k-1)size / (p_in·size + p_out(k-1)size)`` — used to validate that
    every diffusion + sweep recovers the planted cluster from an inside seed.
    """
    rng = np.random.default_rng(seed)
    n = k * size
    blocks = np.arange(n) // size
    edges = []
    # within-block edges
    for b in range(k):
        lo = b * size
        nb = rng.binomial(size * (size - 1) // 2, p_in)
        u = rng.integers(lo, lo + size, size=2 * nb + 16)
        v = rng.integers(lo, lo + size, size=2 * nb + 16)
        ok = u != v
        edges.append(np.stack([u[ok][:nb], v[ok][:nb]], axis=1))
    # between-block edges
    nb = rng.binomial(n * (n - 1) // 2, p_out)
    u = rng.integers(0, n, size=4 * nb + 16)
    v = rng.integers(0, n, size=4 * nb + 16)
    ok = blocks[u] != blocks[v]
    edges.append(np.stack([u[ok][:nb], v[ok][:nb]], axis=1))
    return build_csr(np.concatenate(edges, axis=0), n)


def ba(n: int, m0: int = 4, seed: int = 0) -> CSRGraph:
    """Barabási–Albert preferential attachment (vectorized approximation:
    attach to endpoints of uniformly sampled existing edges)."""
    rng = np.random.default_rng(seed)
    src_list = [np.arange(1, m0 + 1, dtype=np.int64)]
    dst_list = [np.zeros(m0, dtype=np.int64)]
    endpoints = np.concatenate([src_list[0], dst_list[0]])
    for v in range(m0 + 1, n):
        # preferential attachment == uniform over current edge endpoints
        targets = np.unique(rng.choice(endpoints, size=m0))
        s = np.full(targets.shape[0], v, dtype=np.int64)
        src_list.append(s)
        dst_list.append(targets)
        endpoints = np.concatenate([endpoints, s, targets])
    src = np.concatenate(src_list)
    dst = np.concatenate(dst_list)
    return build_csr(np.stack([src, dst], axis=1), n)


_FAMILIES = {
    "randLocal": lambda **kw: rand_local(kw.get("n", 100_000), kw.get("degree", 5), kw.get("seed", 0)),
    "3D-grid": lambda **kw: grid3d(kw.get("side", 40), kw.get("torus", False)),
    "rmat": lambda **kw: rmat(kw.get("scale", 14), kw.get("edge_factor", 8), seed=kw.get("seed", 0)),
    "sbm": lambda **kw: sbm(kw.get("k", 20), kw.get("size", 200), kw.get("p_in", 0.2),
                            kw.get("p_out", 0.0005), kw.get("seed", 0)),
    "ba": lambda **kw: ba(kw.get("n", 20_000), kw.get("m0", 4), kw.get("seed", 0)),
}


def make_graph(family: str, **kw) -> CSRGraph:
    if family not in _FAMILIES:
        raise ValueError(f"unknown graph family {family!r}; options {sorted(_FAMILIES)}")
    return _FAMILIES[family](**kw)
