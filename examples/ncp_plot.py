"""Generate an NCP (network community profile, paper Fig 10) plot.

    PYTHONPATH=src python examples/ncp_plot.py [--graph sbm|randLocal]
Writes experiments/ncp_plot.png (matplotlib) + CSV.
"""
import argparse
import os

import numpy as np

from repro.graphs import sbm, rand_local
from repro.core import ncp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="sbm", choices=["sbm", "randLocal"])
    ap.add_argument("--seeds", type=int, default=48)
    args = ap.parse_args()
    if args.graph == "sbm":
        g = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
    else:
        g = rand_local(50_000, degree=5, seed=0)

    res = ncp(g, num_seeds=args.seeds, alphas=(0.01, 0.05),
              epss=(1e-6, 1e-7), batch=16, cap_n=1 << 10,
              sweep_cap_e=1 << 17)
    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments")
    os.makedirs(out_dir, exist_ok=True)
    ok = np.isfinite(res.best_conductance)
    sizes, conds = res.sizes[ok], res.best_conductance[ok]
    with open(os.path.join(out_dir, f"ncp_{args.graph}.csv"), "w") as f:
        f.write("size,best_conductance\n")
        for s, c in zip(sizes, conds):
            f.write(f"{s},{c:.6f}\n")
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
        plt.figure(figsize=(6, 4))
        plt.loglog(sizes, conds, ".-", ms=3, lw=0.7)
        plt.xlabel("cluster size")
        plt.ylabel("best conductance φ")
        plt.title(f"NCP — {args.graph} ({res.num_runs} runs)")
        plt.grid(True, which="both", alpha=0.3)
        png = os.path.join(out_dir, "ncp_plot.png")
        plt.savefig(png, dpi=130, bbox_inches="tight")
        print("wrote", png)
    except Exception as e:
        print("matplotlib unavailable:", e)
    print(f"min φ = {conds.min():.4f} at size {int(sizes[np.argmin(conds)])}")


if __name__ == "__main__":
    main()
