"""Serving telemetry: the metrics registry the scheduler feeds *and* reads.

The deadline scheduler (serve/scheduler.py) is a control loop: it measures
per-pool tick cost, estimates slack, and orders work by it.  Those
measurements have to live somewhere both observable (exported as JSON for
dashboards / the `benchmarks/serve_bench.py` artifact) and readable back by
the planner (the tick-cost EMAs *are* the cost model).  This module is that
place — a small, dependency-free registry of four metric kinds:

  * ``Counter``  — monotonically increasing int (deadline misses, rejects).
  * gauge        — last-write-wins float (queue depth, slot occupancy).
  * ``EMA``      — exponential moving average (per-pool tick wall-time; the
                   planner's cost estimate, see ``AsyncClusterEngine._plan``).
  * ``Histogram``— latency distribution with log-spaced buckets plus a
                   bounded reservoir for p50/p95/p99 (exact up to the
                   reservoir size, sampled beyond it — good enough for a
                   serving dashboard, deterministic for tests).

Metric names are slash-paths; per-pool metrics use the pool's label
(:func:`pool_label`), e.g. ``pool/pr_nibble:dense:xla:(True, 1.0):b0/tick_latency``.
The registry is thread-safe: ``submit()`` runs on caller threads while the
drive loop records from the scheduler thread.

``snapshot()`` returns a plain-JSON-able dict; ``to_json()`` serializes it.
Telemetry never influences results — it observes scheduling, and scheduling
never changes answers (docs/algorithms.md, bit-identity guarantee #3).
"""
from __future__ import annotations

import bisect
import json
import random
import threading
import time
from collections import deque
from typing import Dict, List, Optional

__all__ = ["Counter", "EMA", "Histogram", "MetricsRegistry", "pool_label",
           "load_cost_table", "lookup_cost", "SNAPSHOT_SCHEMA"]

# Bumped whenever the snapshot shape changes; lets accumulated BENCH_*.json
# artifacts be compared across PRs without guessing their vintage.
SNAPSHOT_SCHEMA = "repro.serve.metrics/v1"


def pool_label(key: tuple) -> str:
    """Stable human-readable label for an engine pool key
    ``(method, backend, statics, ops_backend, bucket[, topo])``.

    ``topo`` — the shard topology ``(axis, num_shards)`` of a ``dist`` pool,
    None for local pools — is folded into the backend segment
    (``dist@data8``), so dist pools never alias dense/sparse pools in JSON
    exports and the EDF planner's per-label cost EMAs stay per-topology.
    Legacy 5-tuple keys label identically to before.
    """
    method, backend, statics, ops_backend, bucket = key[:5]
    topo = key[5] if len(key) > 5 else None
    if topo is not None:
        axis, shards = topo
        backend = f"{backend}@{axis}{shards}"
    return f"{method}:{backend}:{ops_backend}:{statics}:b{bucket}"


def load_cost_table(src) -> Dict[str, float]:
    """Characterized tick costs for the EDF planner's cold start, from a
    ``serve_bench --characterize`` artifact (path or parsed dict; see
    benchmarks/baselines/tick_costs.json).  Entries map pool labels — and
    coarser ``"method:backend"`` fallbacks — to mean tick seconds; a missing
    or malformed source degrades to an empty table, never an error (the
    planner falls back to its built-in default cost)."""
    if src is None:
        return {}
    if isinstance(src, dict):
        doc = src
    else:
        try:
            with open(src) as fh:
                doc = json.load(fh)
        except (OSError, ValueError):
            return {}
    entries = doc.get("entries", doc) if isinstance(doc, dict) else {}
    out = {}
    for k, v in entries.items():
        try:
            out[str(k)] = float(v)
        except (TypeError, ValueError):
            continue
    return out


def lookup_cost(table: Dict[str, float], key: tuple) -> Optional[float]:
    """The characterized tick cost for a pool key: exact label first
    (:func:`pool_label`), then the ``"method:backend"`` family average —
    bucket/statics shift cost far less than the method/backend pair does."""
    if not table:
        return None
    cost = table.get(pool_label(key))
    if cost is not None:
        return cost
    return table.get(f"{key[0]}:{key[1]}")


class Counter:
    """Monotonic event counter.  ``inc`` is locked: counters are bumped from
    caller threads (``submit``'s submitted/rejected) concurrently with the
    drive loop, and a bare ``+=`` would lose increments."""

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value = 0
        self._lock = threading.Lock()

    def inc(self, k: int = 1) -> None:
        with self._lock:
            self.value += k


class EMA:
    """Exponential moving average; ``value`` is None until the first update.

    The scheduler's per-pool tick-cost estimate: robust to the one-off
    compile-time spike of a pool's first tick (it decays at rate ``alpha``)
    while tracking drift as lane occupancy changes.
    """

    __slots__ = ("alpha", "value")

    def __init__(self, alpha: float = 0.3) -> None:
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        x = float(x)
        self.value = x if self.value is None else (
            (1.0 - self.alpha) * self.value + self.alpha * x)
        return self.value


# Log-spaced latency bucket bounds (seconds): 1 µs .. ~100 s, ×~3.16/decade.
_BUCKET_BOUNDS = tuple(10.0 ** (e / 2.0) for e in range(-12, 5))


class Histogram:
    """Latency histogram: log-spaced bucket counts + a bounded reservoir.

    ``percentile(q)`` is exact while ``count <= reservoir`` (every sample
    retained) and a uniform subsample beyond that (deterministic RNG so test
    runs reproduce).  Bucket counts are always exact and exported alongside.
    """

    def __init__(self, reservoir: int = 4096) -> None:
        self.count = 0
        self.sum = 0.0
        self.buckets = [0] * (len(_BUCKET_BOUNDS) + 1)
        self._cap = reservoir
        self._samples: List[float] = []
        self._rng = random.Random(0)

    def record(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        self.buckets[bisect.bisect_right(_BUCKET_BOUNDS, x)] += 1
        if len(self._samples) < self._cap:
            self._samples.append(x)
        else:  # reservoir sampling: keep each sample with prob cap/count
            j = self._rng.randrange(self.count)
            if j < self._cap:
                self._samples[j] = x

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]; None while empty."""
        if not self._samples:
            return None
        s = sorted(self._samples)
        idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[idx]

    def summary(self) -> Dict:
        return dict(count=self.count, sum=self.sum,
                    mean=(self.sum / self.count) if self.count else None,
                    p50=self.percentile(50), p95=self.percentile(95),
                    p99=self.percentile(99))


class MetricsRegistry:
    """Create-or-get registry of counters / gauges / EMAs / histograms."""

    def __init__(self, max_postmortems: int = 16) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, float] = {}
        self._emas: Dict[str, EMA] = {}
        self._hists: Dict[str, Histogram] = {}
        # Deadline-expiry victims' span trees (serve/tracing.py), newest
        # kept: a bounded flight-recorder tail, not an unbounded log.
        self._postmortems: deque = deque(maxlen=max_postmortems)

    # -- create-or-get -------------------------------------------------------

    def counter(self, name: str) -> Counter:
        with self._lock:
            return self._counters.setdefault(name, Counter())

    def ema(self, name: str, alpha: float = 0.3) -> EMA:
        with self._lock:
            return self._emas.setdefault(name, EMA(alpha))

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            return self._hists.setdefault(name, Histogram())

    # -- record shortcuts ----------------------------------------------------

    def inc(self, name: str, k: int = 1) -> None:
        self.counter(name).inc(k)

    def set_gauge(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).record(value)

    # -- read ----------------------------------------------------------------

    def counter_value(self, name: str) -> int:
        with self._lock:
            c = self._counters.get(name)
        return c.value if c is not None else 0

    def ema_value(self, name: str) -> Optional[float]:
        with self._lock:
            e = self._emas.get(name)
        return e.value if e is not None else None

    def gauge_value(self, name: str) -> Optional[float]:
        with self._lock:
            return self._gauges.get(name)

    # -- postmortems ---------------------------------------------------------

    def add_postmortem(self, record: Dict) -> None:
        """Attach a deadline-miss postmortem (a JSON-able span tree from
        ``Tracer.request_tree`` plus request context).  Bounded deque —
        oldest victims roll off."""
        with self._lock:
            self._postmortems.append(record)

    def postmortems(self) -> List[Dict]:
        with self._lock:
            return list(self._postmortems)

    # -- export --------------------------------------------------------------

    def snapshot(self) -> Dict:
        """Plain-dict view (JSON-able) of every metric, under a versioned
        header so accumulated artifacts are comparable across PRs."""
        with self._lock:
            return dict(
                schema=SNAPSHOT_SCHEMA,
                generated_unix=time.time(),
                counters={k: c.value for k, c in self._counters.items()},
                gauges=dict(self._gauges),
                emas={k: e.value for k, e in self._emas.items()},
                histograms={k: h.summary() for k, h in self._hists.items()},
                postmortems=list(self._postmortems),
            )

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)
