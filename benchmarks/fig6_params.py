"""Figure 6 reproduction: runtime & conductance vs parameter settings.

Paper trends (C5), all on one graph from one seed:
  Nibble:      T↑ or ε↓  ⇒ time↑, conductance↓
  PR-Nibble:   ε↓        ⇒ time↑, conductance↓
  HK-PR:       N↑ or ε↓  ⇒ time↑, conductance↓
  rand-HK-PR:  N↑ or K↑  ⇒ time↑, conductance↓
"""
import numpy as np
import jax

from repro.core import (nibble, pr_nibble, hk_pr, rand_hk_pr, sweep_cut,
                        sweep_cut_dense)
from .common import get_graph, emit, timeit


def _cond(g, p):
    return float(sweep_cut_dense(g, p, 1 << 12, 1 << 18).best_conductance)


def run(graph_name: str = "sbm-planted"):
    g = get_graph(graph_name)
    seed = 5 if graph_name == "sbm-planted" else int(np.argmax(np.asarray(g.deg)))

    for T in (5, 10, 20):
        for eps in (1e-6, 1e-7, 1e-8):
            us, res = timeit(nibble, g, seed, eps, T, repeats=1)
            emit(f"fig6/nibble/T={T},eps={eps:g}", us,
                 f"cond={_cond(g, res.p):.4f};work={int(res.edge_work)}")

    for eps in (1e-5, 1e-6, 1e-7):
        us, res = timeit(pr_nibble, g, seed, eps, 0.01, repeats=1)
        emit(f"fig6/pr_nibble/eps={eps:g}", us,
             f"cond={_cond(g, res.p):.4f};pushes={int(res.pushes)}")

    for N in (5, 10, 20):
        for eps in (1e-5, 1e-7):
            us, res = timeit(hk_pr, g, seed, N, eps, 10.0, repeats=1)
            emit(f"fig6/hk_pr/N={N},eps={eps:g}", us,
                 f"cond={_cond(g, res.p):.4f};work={int(res.edge_work)}")

    for NW in (1024, 4096):
        for K in (5, 10, 20):
            us, res = timeit(rand_hk_pr, g, seed, NW, K, 10.0,
                             jax.random.PRNGKey(0), repeats=1)
            sw = sweep_cut(g, res.ids, res.vals, res.nnz, 1 << 18)
            emit(f"fig6/rand_hk/N={NW},K={K}", us,
                 f"cond={float(sw.best_conductance):.4f}")


if __name__ == "__main__":
    run()
