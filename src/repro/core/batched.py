"""Batched multi-seed local clustering (paper §5's outer parallelism axis).

"A straightforward way to use parallelism is to run many local graph
computations independently in parallel" — this module makes that the
first-class path instead of an NCP-only special case.  The fixed-capacity
frontier drivers (:func:`pr_nibble_fixedcap`, :func:`hk_pr_fixedcap`) and the
Theorem-1 sweep cut are vmapped over a ``seeds[B]`` axis with *per-seed*
``(ε, α)`` parameters and *shared* static ``(cap_f, cap_e)`` capacities, so a
whole batch of queries is one XLA dispatch and one compile-cache entry.

XLA's while-loop batching rule masks finished lanes (the carry is
``select(pred, new, old)`` per lane), so each lane's state trajectory is
*identical* to running the single-seed driver — batching changes throughput,
never results.

Overflow keeps the bucketed-recompilation contract of the single-seed
drivers, but per seed: lanes whose frontier or edge workspace overflowed are
repacked into a power-of-two-sized retry batch at the next capacity bucket
(same doubling schedule as :func:`repro.core.pr_nibble.pr_nibble`, so the
per-seed results stay bit-identical to the single-seed path).  The whole
batch therefore compiles at most O(log) distinct bucket shapes, all reused
from the jit cache across calls — the property `LocalClusterEngine`
(serve/cluster_engine.py) builds its compiled-shape LRU on.

Capacity-ladder semantics (shared with core/batched_sparse.py):

  * Every jitted kernel takes *static* capacities; one (batch, caps) tuple
    is one compiled shape ("bucket").  Bucket b has caps ``base << b``.
  * Ladder step (``_CapLadder.advance``): ``cap_f`` and the sparse value
    capacity ``cap_v`` double but clamp at ``n + 1`` (a frontier/support can
    never exceed every vertex + sentinel); ``cap_e`` doubles unclamped until
    ``max_cap_e``; the sweep caps ``cap_n``/``sweep_cap_e`` clamp at
    ``n`` / nothing.  This is verbatim the single-seed drivers' schedule —
    the bit-identity guarantee depends on dispatching the *same* static
    shapes the single-seed retry loop would.
  * Retry contract (``_bucketed_retry``): after each dispatch, lanes whose
    overflow flag is set are repacked (padded to a power of two by cycling
    lanes) and re-dispatched one bucket up; lanes that finish are written
    to the output buffers exactly once.  When the ladder is exhausted
    (``cap_e ≥ max_cap_e``) overflowed lanes are written as-is with their
    flag set, matching the single-seed drivers.
  * Recompile boundary: a fresh (batch_pow2, caps) pair.  A B-seed call
    therefore compiles ≤ O(log B · log(max_cap_e/cap_e)) shapes, all shared
    process-wide through the jit cache.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from . import ops as _ops
from .frontier import next_pow2
from .pr_nibble import MAX_ITERS, pr_nibble_fixedcap
from .hk_pr import hk_pr_fixedcap
from .sweep import sweep_cut_dense

__all__ = [
    "BatchedDiffusionResult", "BatchedClusterResult",
    "batched_pr_nibble_fixedcap", "batched_hk_pr_fixedcap",
    "batched_sweep_cut", "batched_cluster_fixedcap",
    "batched_pr_nibble", "batched_hk_pr", "batched_cluster",
    "rounds_remaining_hint", "hk_rounds_remaining",
    "LaneKernels", "dense_lane_kernels", "STATUS_ROWS",
    "STATUS_FINISHED", "STATUS_OVERFLOW", "STATUS_FRONTIER",
    "STATUS_ITER", "STATUS_PUSHES", "STATUS_EXCHANGED",
]


# ----------------------------------------------- scheduler cost-model hints

def rounds_remaining_hint(iterations, frontier_count,
                          max_iters: int = MAX_ITERS) -> np.ndarray:
    """Per-lane pending-push-rounds estimate for latency-aware schedulers.

    PR-Nibble has no closed-form round count — termination depends on how the
    residual drains — so the serving scheduler (serve/scheduler.py) needs a
    cheap host-side predictor to turn "EMA tick cost" into "estimated time to
    finish".  This uses two observables of the lane state:

      * ``frontier_count == 0`` → the lane is finished: 0 rounds remain.
      * otherwise, a survival ("Lindy") estimate: a run that has already
        pushed ``t`` rounds is expected to push about ``t`` more, clamped to
        ``[1, max_iters - t]``.  Push-round counts across seeds are
        heavy-tailed (the NCP sweeps make this visible), where this estimator
        is the right crude prior; it deliberately under-promises early
        (t small → short estimate, refined every tick as t grows).

    Vectorized over lanes: ``iterations`` / ``frontier_count`` are int-like
    [B] (scalars broadcast); returns int64[B] estimated rounds remaining.
    This is a *hint* — scheduling consumes it, results never depend on it.
    """
    it = np.atleast_1d(np.asarray(iterations, np.int64))
    fc = np.atleast_1d(np.asarray(frontier_count, np.int64))
    rem = np.clip(it, 1, np.maximum(max_iters - it, 1))
    return np.where(fc > 0, rem, 0)


def hk_rounds_remaining(j, done, frontier_count, N: int) -> np.ndarray:
    """Exact pending-rounds count for HK-PR lanes: the rounds are Taylor
    levels, so an alive lane at level ``j`` has exactly ``N - j`` left
    (0 when ``done`` or the frontier emptied).  Same [B] conventions as
    :func:`rounds_remaining_hint`."""
    j = np.atleast_1d(np.asarray(j, np.int64))
    done = np.atleast_1d(np.asarray(done, bool))
    fc = np.atleast_1d(np.asarray(frontier_count, np.int64))
    return np.where(done | (fc == 0), 0, np.maximum(N - j, 0))


# ------------------------------------------------------------ jitted kernels

@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8),
                   static_argnames=("optimized", "cap_f", "cap_e",
                                    "max_iters", "beta", "backend"))
def batched_pr_nibble_fixedcap(graph: CSRGraph, seeds, eps, alpha,
                               optimized: bool, cap_f: int, cap_e: int,
                               max_iters: int = MAX_ITERS, beta: float = 1.0,
                               *, backend: str = "xla"):
    """vmap of :func:`pr_nibble_fixedcap`: seeds[B] with per-seed (eps, alpha).

    Shapes: ``seeds`` int32[B], ``eps``/``alpha`` f32[B]; returns a
    :class:`PRNibbleResult` whose leaves carry a leading [B] axis
    (``p``/``r`` f32[B, n], counters int32[B], ``overflow`` bool[B]).
    """
    def one(s, e, a):
        return pr_nibble_fixedcap(graph, s, e, a, optimized, cap_f, cap_e,
                                  max_iters, beta, backend=backend)
    return jax.vmap(one)(seeds, eps, alpha)


@functools.partial(jax.jit, static_argnums=(2, 4, 5, 6),
                   static_argnames=("N", "t", "cap_f", "cap_e", "backend"))
def batched_hk_pr_fixedcap(graph: CSRGraph, seeds, N: int, eps, t: float,
                           cap_f: int, cap_e: int, *, backend: str = "xla"):
    """vmap of :func:`hk_pr_fixedcap`: seeds[B] with per-seed eps (N, t static).

    Shapes: ``seeds`` int32[B], ``eps`` f32[B]; result leaves lead with [B].
    """
    def one(s, e):
        return hk_pr_fixedcap(graph, s, N, e, t, cap_f, cap_e,
                              backend=backend)
    return jax.vmap(one)(seeds, eps)


@functools.partial(jax.jit, static_argnums=(2, 3),
                   static_argnames=("cap_n", "cap_e", "backend"))
def batched_sweep_cut(graph: CSRGraph, p, cap_n: int, cap_e: int, *,
                      backend: str = "xla"):
    """vmap of :func:`sweep_cut_dense` over p[B, n] diffusion vectors.

    ``p`` is f32[B, n]; returns a :class:`SweepResult` with leading [B] axis
    (curves f32[B, min(cap_n, n)], scalars → [B]).  See
    :func:`repro.core.batched_sparse.batched_sparse_sweep_cut` for the
    O(cap_n + cap_e)-per-lane variant that never touches f32[n].
    """
    return jax.vmap(
        lambda q: sweep_cut_dense(graph, q, cap_n, cap_e, backend))(p)


class _ClusterLanes(NamedTuple):
    """Per-lane output of the fused diffusion+sweep kernel."""
    conductance: jnp.ndarray       # f32[B, cap_n] — full sweep curve
    best_conductance: jnp.ndarray  # f32[B]
    best_size: jnp.ndarray         # int32[B]
    best_volume: jnp.ndarray       # int32[B]
    order: jnp.ndarray             # int32[B, cap_n] — sweep order (cluster prefix)
    support: jnp.ndarray           # int32[B] — nnz of the diffusion
    pushes: jnp.ndarray            # int32[B]
    iterations: jnp.ndarray        # int32[B]
    overflow: jnp.ndarray          # bool[B] — diffusion OR sweep overflow


@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8, 9),
                   static_argnames=("optimized", "cap_f", "cap_e", "cap_n",
                                    "sweep_cap_e", "beta", "backend"))
def batched_cluster_fixedcap(graph: CSRGraph, seeds, eps, alpha,
                             optimized: bool, cap_f: int, cap_e: int,
                             cap_n: int, sweep_cap_e: int,
                             beta: float = 1.0, *,
                             backend: str = "xla") -> _ClusterLanes:
    """Fused PR-Nibble + sweep cut per seed — the NCP/serving inner kernel.

    Unlike the plain diffusion kernels this never materializes p[B, n] in the
    result: each lane reduces to its sweep curve + summary stats.
    """
    def one(s, e, a):
        res = pr_nibble_fixedcap(graph, s, e, a, optimized, cap_f, cap_e,
                                 MAX_ITERS, beta, backend=backend)
        sw = sweep_cut_dense(graph, res.p, cap_n, sweep_cap_e, backend)
        return _ClusterLanes(
            conductance=sw.conductance,
            best_conductance=sw.best_conductance,
            best_size=sw.best_size,
            best_volume=sw.best_volume,
            order=sw.order,
            support=sw.nnz,
            pushes=res.pushes,
            iterations=res.iterations,
            overflow=res.overflow | sw.overflow,
        )
    return jax.vmap(one)(seeds, eps, alpha)


# ------------------------------------------------- host drivers (per-seed retry)

class BatchedDiffusionResult(NamedTuple):
    p: np.ndarray           # f32[B, n]
    r: np.ndarray           # f32[B, n] (zeros for HK-PR, which has no residual out)
    iterations: np.ndarray  # int32[B]
    pushes: np.ndarray      # int32[B]
    edge_work: np.ndarray   # int32[B]
    overflow: np.ndarray    # bool[B] — True only if max_cap_e was exhausted
    buckets: Tuple[Tuple[int, int, int], ...]  # (batch, cap_f, cap_e) dispatched


class BatchedClusterResult(NamedTuple):
    conductance: np.ndarray       # f32[B, cap_n] — full sweep curves
    best_conductance: np.ndarray  # f32[B]
    best_size: np.ndarray         # int32[B]
    best_volume: np.ndarray       # int32[B]
    support: np.ndarray           # int32[B]
    pushes: np.ndarray            # int32[B]
    iterations: np.ndarray        # int32[B]
    overflow: np.ndarray          # bool[B]
    buckets: Tuple[Tuple[int, int, int], ...]


def _prep_batch(seeds, *params):
    seeds = np.atleast_1d(np.asarray(seeds, np.int32))
    B = seeds.shape[0]
    out = [np.broadcast_to(np.asarray(p, np.float32), (B,)).astype(np.float32)
           for p in params]
    return (seeds, B, *out)


def _retry_sizes(k: int, B: int) -> int:
    """Retry batches are padded to the next power of two (≤ the original B)
    so the whole run touches at most O(log B · log cap) compiled shapes."""
    return min(next_pow2(max(k, 1)), next_pow2(B))


_annotate = None


def _trace_annotate(name, **attrs):
    """Forward to the serving layer's ambient tracing hook
    (:func:`repro.serve.tracing.annotate`) — a no-op unless a Tracer scope
    is active on this thread.  Imported lazily at first call: core must not
    import ``repro.serve`` at module time (serve imports core back), and the
    serve layer is optional for pure-core users."""
    global _annotate
    if _annotate is None:
        try:
            from repro.serve.tracing import annotate as _annotate
        except Exception:                       # serve layer unavailable
            _annotate = lambda name, **attrs: None
    _annotate(name, **attrs)


def _bucketed_retry(B, dispatch, advance, exhausted, outputs, ovf_out):
    """Shared per-seed retry ladder for the host drivers.

    ``dispatch(sel)`` runs the current capacity bucket for the padded lane
    selection ``sel`` and returns ``(fields, bucket)``: ``fields`` maps each
    output name (plus "overflow") to an np array with leading axis
    ``len(sel)``; ``bucket`` is the (batch, cap_f, cap_e) key recorded for
    the compile-shape accounting.  ``advance()`` doubles the capacities;
    ``exhausted()`` reports the ladder's end (overflowed lanes are then
    written as-is with their flag set, matching the single-seed drivers).
    """
    pending = np.arange(B)
    buckets = []
    while True:
        k = pending.size
        sel = np.resize(pending, _retry_sizes(k, B))  # pad by cycling lanes
        fields, bucket = dispatch(sel)
        buckets.append(bucket)
        o = np.asarray(fields["overflow"])[:k]
        # Paper-native work measures for an active trace scope (serve layer):
        # one event per ladder dispatch — bucket shape, lanes served,
        # overflow count, total pushes, dist exchange volume when present.
        obs = dict(bucket=tuple(int(b) for b in bucket), lanes=int(k),
                   hop=len(buckets) - 1, overflowed=int(o.sum()))
        for extra in ("pushes", "exchanged"):
            if extra in fields:
                obs[extra] = int(np.asarray(fields[extra])[:k].sum())
        _trace_annotate("ladder_dispatch", **obs)
        final = (not o.any()) or exhausted()
        done = pending if final else pending[~o]
        take = slice(None) if final else ~o
        for name, buf in outputs.items():
            vals = np.asarray(fields[name])[:k][take]
            if buf.ndim == 2 and vals.shape[1] != buf.shape[1]:
                m = min(vals.shape[1], buf.shape[1])  # grown sweep grid
                buf[done, :m] = vals[:, :m]
            else:
                buf[done] = vals
        ovf_out[done] = o[take]
        if final:
            return tuple(buckets)
        pending = pending[o]
        advance()


class _CapLadder:
    """The single-seed drivers' doubling schedule, shared by retries.

    Generalized over every per-lane capacity, not just the vertex-count-like
    ones: ``cap_f`` (frontier slots), ``cap_e`` (edge workspace), and
    optionally ``cap_v`` (SparseVec value slots, the sparse backend's K),
    ``cap_n``/``sweep_cap_e`` (sweep grid / sweep edge workspace), and
    ``cap_x`` (the distributed path's per-owner exchange buckets, clamped
    at ``cap_e``).  ``None`` capacities are absent from the schedule.
    """

    def __init__(self, n, cap_f, cap_e, max_cap_e, cap_n=None, sweep_cap_e=None,
                 cap_v=None, cap_x=None):
        self.n, self.cap_f, self.cap_e, self.max_cap_e = n, cap_f, cap_e, max_cap_e
        self.cap_n, self.sweep_cap_e = cap_n, sweep_cap_e
        self.cap_v = cap_v
        self.cap_x = cap_x

    def exhausted(self):
        return self.cap_e >= self.max_cap_e

    def advance(self):
        self.cap_f = min(self.cap_f * 2, self.n + 1)
        self.cap_e = self.cap_e * 2
        if self.cap_v is not None:
            self.cap_v = min(self.cap_v * 2, self.n + 1)
        if self.cap_n is not None:
            self.cap_n = min(self.cap_n * 2, self.n)
        if self.sweep_cap_e is not None:
            self.sweep_cap_e = self.sweep_cap_e * 2
        if self.cap_x is not None:
            # per-owner exchange buckets (distributed path): a bucket can
            # never usefully exceed the edge workspace that fills it
            self.cap_x = min(self.cap_x * 2, self.cap_e)


def batched_pr_nibble(graph: CSRGraph, seeds, eps=1e-7, alpha=0.01,
                      optimized: bool = True, cap_f: int = 1 << 12,
                      cap_e: int = 1 << 16, max_cap_e: int = 1 << 26,
                      beta: float = 1.0, max_iters: int = MAX_ITERS,
                      backend: str = "xla") -> BatchedDiffusionResult:
    """Batched bucketed driver: one dispatch per capacity bucket, per-seed
    overflow retry.  Per-seed output is identical to looping
    :func:`repro.core.pr_nibble.pr_nibble` (same capacity schedule).

    ``seeds`` is int-like[B] (scalars broadcast); ``eps``/``alpha`` broadcast
    to f32[B].  Returns host-side numpy: ``p``/``r`` f32[B, n], counters
    int32[B], ``overflow`` bool[B] (True only if max_cap_e was exhausted),
    and the dispatched ``buckets`` tuple for compile-shape accounting.
    """
    graph = _ops.local_csr(graph)   # any graph-like (GraphHandle ok)
    seeds, B, eps, alpha = _prep_batch(seeds, eps, alpha)
    n = graph.n
    out = dict(p=np.zeros((B, n), np.float32), r=np.zeros((B, n), np.float32),
               iterations=np.zeros(B, np.int32), pushes=np.zeros(B, np.int32),
               edge_work=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    lad = _CapLadder(n, cap_f, cap_e, max_cap_e)

    def dispatch(sel):
        res = batched_pr_nibble_fixedcap(
            graph, jnp.asarray(seeds[sel]), jnp.asarray(eps[sel]),
            jnp.asarray(alpha[sel]), optimized, lad.cap_f, lad.cap_e,
            max_iters, beta, backend=backend)
        return res._asdict(), (sel.size, lad.cap_f, lad.cap_e)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out, ovf)
    return BatchedDiffusionResult(overflow=ovf, buckets=buckets, **out)


def batched_hk_pr(graph: CSRGraph, seeds, N: int = 20, eps=1e-7,
                  t: float = 10.0, cap_f: int = 1 << 12, cap_e: int = 1 << 16,
                  max_cap_e: int = 1 << 26,
                  backend: str = "xla") -> BatchedDiffusionResult:
    """Batched bucketed HK-PR driver, mirroring :func:`batched_pr_nibble`."""
    graph = _ops.local_csr(graph)   # any graph-like (GraphHandle ok)
    seeds, B, eps = _prep_batch(seeds, eps)
    n = graph.n
    out = dict(p=np.zeros((B, n), np.float32),
               iterations=np.zeros(B, np.int32), pushes=np.zeros(B, np.int32),
               edge_work=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    lad = _CapLadder(n, cap_f, cap_e, max_cap_e)

    def dispatch(sel):
        res = batched_hk_pr_fixedcap(graph, jnp.asarray(seeds[sel]), N,
                                     jnp.asarray(eps[sel]), t,
                                     lad.cap_f, lad.cap_e, backend=backend)
        return res._asdict(), (sel.size, lad.cap_f, lad.cap_e)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out, ovf)
    return BatchedDiffusionResult(r=np.zeros((B, n), np.float32),
                                  overflow=ovf, buckets=buckets, **out)


def batched_cluster(graph: CSRGraph, seeds, eps=1e-6, alpha=0.01,
                    optimized: bool = True, cap_f: int = 1 << 12,
                    cap_e: int = 1 << 16, cap_n: int = 1 << 12,
                    sweep_cap_e: int = 1 << 18, max_cap_e: int = 1 << 26,
                    beta: float = 1.0,
                    backend: str = "xla") -> BatchedClusterResult:
    """Batched PR-Nibble + sweep with per-seed retry on *either* the
    diffusion or sweep workspace overflowing (all capacities double).

    Sweep curves are reported on the fixed ``min(cap_n, n)`` grid of the
    first bucket so the NCP accumulator sees one consistent size axis.
    """
    graph = _ops.local_csr(graph)   # any graph-like (GraphHandle ok)
    seeds, B, eps, alpha = _prep_batch(seeds, eps, alpha)
    n = graph.n
    grid = min(cap_n, n)
    out = dict(conductance=np.full((B, grid), np.inf, np.float32),
               best_conductance=np.full(B, np.inf, np.float32),
               best_size=np.zeros(B, np.int32),
               best_volume=np.zeros(B, np.int32),
               support=np.zeros(B, np.int32),
               pushes=np.zeros(B, np.int32),
               iterations=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    lad = _CapLadder(n, cap_f, cap_e, max_cap_e, cap_n=grid,
                     sweep_cap_e=sweep_cap_e)

    def dispatch(sel):
        res = batched_cluster_fixedcap(
            graph, jnp.asarray(seeds[sel]), jnp.asarray(eps[sel]),
            jnp.asarray(alpha[sel]), optimized, lad.cap_f, lad.cap_e,
            min(lad.cap_n, n), lad.sweep_cap_e, beta, backend=backend)
        fields = res._asdict()
        fields.pop("order")            # not part of the host result
        return fields, (sel.size, lad.cap_f, lad.cap_e)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out, ovf)
    return BatchedClusterResult(overflow=ovf, buckets=buckets, **out)


# ------------------------------------------- executable-shaped lane kernels
# The serving engine (serve/cluster_engine.py) steps resident lane pools
# through exactly the round functions above, but needs them packaged as
# *executables*: fixed-signature jits it can AOT-lower (.lower().compile())
# per pool shape, with the lane state donated so a tick updates the pool
# buffers in place.  These factories are that packaging — one LaneKernels
# bundle per (n, method, statics, caps, rounds, backend) shape, lru_cached
# so every engine instance (and every pool re-creation after LRU eviction)
# shares one set of jit objects process-wide.

# Row indices of the stacked int32[STATUS_ROWS, B] per-tick status readback
# (LaneKernels.status): ONE device→host transfer carries every observable
# the engine's harvest/scheduler path needs — finished & overflow flags,
# frontier occupancy, iteration counter, push count, and (dist lanes only)
# exchanged-pair count.  Results never depend on these being fresh; harvest
# correctness does, so the engine pulls them once per tick, post-step.
(STATUS_FINISHED, STATUS_OVERFLOW, STATUS_FRONTIER,
 STATUS_ITER, STATUS_PUSHES, STATUS_EXCHANGED) = range(6)
STATUS_ROWS = 6


class LaneKernels(NamedTuple):
    """Fixed-signature tick kernels for one lane-pool shape.

    ``init(seeds[B]) → state`` (vmapped placeholder build);
    ``inject(state, lane, seed) → state`` (donates ``state``);
    ``step(graph, state, eps[B], alpha[B], active[B]) → state`` (donates
    ``state``; ``alpha`` is ignored by HK-PR but kept in the signature so
    every pool shares one calling convention);
    ``status(state) → int32[STATUS_ROWS, B]`` (the coalesced readback);
    ``sweep(graph, state, lane) → (order, meta_i32[4], φ)`` — the
    harvest-gather: slice one finished lane's diffusion out of the pool and
    sweep it on-device, returning only ``order`` (int32[cap_n] / [cap_v]),
    ``meta = [best_size, best_volume, nnz, overflow]`` and the best
    conductance — never the full pool state.
    """
    init: object
    inject: object
    step: object
    status: object
    sweep: object


@functools.lru_cache(maxsize=None)
def dense_lane_kernels(n: int, method: str, statics: tuple, cap_f: int,
                       cap_e: int, cap_n: int, sweep_cap_e: int,
                       rounds: int, backend: str) -> LaneKernels:
    """Dense-lane kernel bundle: PR-Nibble (``statics = (optimized, β)``)
    or HK-PR (``statics = (N, t)``) over f32[n] state rows.  The step body
    is the same masked while-loop the batched drivers run, so a lane's
    trajectory is bit-identical to the single-seed driver's (guarantee #2);
    donation and AOT lowering change where buffers live, never values
    (guarantee #9)."""
    from .pr_nibble import pr_nibble_init, pr_nibble_round, pr_nibble_alive
    from .hk_pr import hk_pr_init, hk_pr_round, hk_pr_alive
    if method == "pr_nibble":
        optimized, beta = statics
        seed_init = lambda s: pr_nibble_init(s, n, cap_f)
        alive = lambda s: pr_nibble_alive(s, MAX_ITERS)
        rnd = lambda g, s, e, a: pr_nibble_round(g, s, e, a, optimized,
                                                 cap_e, beta, backend)
        iter_of = lambda s: s.t
        done_of = lambda s: jnp.zeros_like(s.overflow)
    elif method == "hk_pr":
        N, t = statics
        seed_init = lambda s: hk_pr_init(s, n, cap_f)
        alive = hk_pr_alive
        rnd = lambda g, s, e, a: hk_pr_round(g, s, N, e, t, cap_e, backend)
        iter_of = lambda s: s.j
        done_of = lambda s: s.done
    else:
        raise ValueError(f"unknown method: {method!r}")

    @jax.jit
    def init(seeds):
        return jax.vmap(seed_init)(seeds)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def inject(state, lane, seed):
        return jax.tree.map(lambda buf, v: buf.at[lane].set(v),
                            state, seed_init(seed))

    @functools.partial(jax.jit, donate_argnums=(1,))
    def step(graph, state, eps, alpha, active):
        def one(s, e, a, act):
            def cond(c):
                s2, k = c
                return act & (k < rounds) & alive(s2)

            def body(c):
                s2, k = c
                return rnd(graph, s2, e, a), k + 1

            s2, _ = jax.lax.while_loop(cond, body,
                                       (s, jnp.asarray(0, jnp.int32)))
            return s2
        return jax.vmap(one)(state, eps, alpha, active)

    @jax.jit
    def status(state):
        fc = state.frontier.count.astype(jnp.int32)
        fin = ((fc == 0) | state.overflow | done_of(state)
               | (iter_of(state) >= MAX_ITERS))
        return jnp.stack([fin.astype(jnp.int32),
                          state.overflow.astype(jnp.int32), fc,
                          iter_of(state).astype(jnp.int32),
                          state.pushes.astype(jnp.int32),
                          jnp.zeros_like(fc)])

    @jax.jit
    def sweep(graph, state, lane):
        sw = sweep_cut_dense(graph, state.p[lane], cap_n, sweep_cap_e,
                             backend)
        meta = jnp.stack([sw.best_size, sw.best_volume, sw.nnz,
                          sw.overflow.astype(jnp.int32)])
        return sw.order, meta, sw.best_conductance

    return LaneKernels(init, inject, step, status, sweep)
