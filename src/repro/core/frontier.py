"""Fixed-capacity frontier machinery — the TPU-native Ligra.

Ligra's ``vertexSubset`` + ``EDGEMAP`` do work proportional to the active
vertices and their edges using dynamic queues and atomics.  Under XLA all
shapes are static, so the same *work-locality* is obtained with:

  * ``Frontier``      — a padded id buffer ``ids[cap]`` + ``count``; invalid
                        slots hold the sentinel ``n`` (one-past-last vertex).
  * ``expand``        — EDGEMAP's edge enumeration: exclusive prefix-sum over
                        frontier degrees, then each of the ``cap_e`` edge slots
                        finds its (frontier slot, within-row offset) with a
                        ``searchsorted`` — O(cap_e log cap_f) work,
                        O(log) depth: exactly the paper's §3 primitives.
  * ``pack_unique``   — the new-frontier ``filter``: sort candidates, mask
                        duplicates + failed predicate, prefix-sum compaction.

Overflow (frontier or edge workspace exceeding capacity) is detected exactly
and surfaced as a flag; drivers retry at the next power-of-two bucket
(`bucketed recompilation` — the static-shape analogue of queue growth, at most
O(log) recompiles per graph).

All functions are pure jnp and usable under jit / vmap / shard_map.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from . import ops

__all__ = ["Frontier", "EdgeBatch", "singleton", "expand", "pack_unique",
           "next_pow2", "DEFAULT_CAPS", "scatter_add_dense",
           "scatter_set_dense", "one_hot_f32"]

DEFAULT_CAPS = dict(cap_f=1 << 12, cap_e=1 << 16)


class Frontier(NamedTuple):
    ids: jnp.ndarray       # int32[cap_f]; invalid slots == sentinel (n)
    count: jnp.ndarray     # int32 scalar — number of valid slots (prefix)
    overflow: jnp.ndarray  # bool scalar — capacity was exceeded

    @property
    def cap(self) -> int:
        return self.ids.shape[0]

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.ids.shape[0], dtype=jnp.int32) < self.count


class EdgeBatch(NamedTuple):
    """Result of expanding a frontier: one slot per (frontier vertex, edge)."""
    slot: jnp.ndarray      # int32[cap_e] — index into frontier ids
    src: jnp.ndarray       # int32[cap_e] — source vertex id (sentinel if invalid)
    dst: jnp.ndarray       # int32[cap_e] — destination vertex id (sentinel if invalid)
    valid: jnp.ndarray     # bool [cap_e]
    total: jnp.ndarray     # int32 scalar — true number of edges
    overflow: jnp.ndarray  # bool scalar


def next_pow2(x: int) -> int:
    return 1 << max(0, int(x - 1).bit_length())


def singleton(v, n: int, cap_f: int) -> Frontier:
    """Frontier containing exactly the seed vertex (paper line 9)."""
    ids = jnp.full((cap_f,), n, dtype=jnp.int32).at[0].set(jnp.asarray(v, jnp.int32))
    return Frontier(ids=ids, count=jnp.asarray(1, jnp.int32),
                    overflow=jnp.asarray(False))


def seed_set(vs: jnp.ndarray, count, n: int, cap_f: int) -> Frontier:
    """Frontier from a multi-vertex seed set (paper footnote 3: "Our codes
    can easily be modified to take as input a seed set with multiple
    vertices"), sentinel-padded to cap_f."""
    vs = jnp.asarray(vs, jnp.int32)
    k = vs.shape[0]
    valid = jnp.arange(k, dtype=jnp.int32) < count
    ids = jnp.full((cap_f,), n, dtype=jnp.int32)
    ids = ids.at[jnp.where(valid, jnp.arange(k), cap_f)].set(
        jnp.where(valid, vs, n), mode="drop")
    return Frontier(ids=ids, count=jnp.asarray(count, jnp.int32),
                    overflow=jnp.asarray(k > cap_f))


def expand(graph: CSRGraph, frontier: Frontier, cap_e: int,
           backend: str = "xla") -> EdgeBatch:
    """Enumerate all edges incident to the frontier into ``cap_e`` slots.

    Work O(cap_e log cap_f), depth O(log) — matches EDGEMAP's
    work-proportional-to-outgoing-edges contract.  ``backend`` routes the
    degree prefix sum through :mod:`repro.core.ops` (int32 — exact on every
    backend).
    """
    n = graph.n
    fvalid = frontier.valid()
    ids = jnp.where(fvalid, frontier.ids, n)
    degs = jnp.where(fvalid, graph.deg[jnp.minimum(ids, n - 1)], 0)
    degs = jnp.where(ids < n, degs, 0).astype(jnp.int32)
    offs = ops.prefix_sum(degs, backend=backend) - degs  # exclusive prefix sum
    total = offs[-1] + degs[-1]
    j = jnp.arange(cap_e, dtype=jnp.int32)
    # frontier slot owning edge slot j: last i with offs[i] <= j
    slot = jnp.searchsorted(offs, j, side="right").astype(jnp.int32) - 1
    slot = jnp.clip(slot, 0, frontier.cap - 1)
    within = j - offs[slot]
    valid = j < total
    src = jnp.where(valid, ids[slot], n)
    base = graph.indptr[jnp.minimum(src, n - 1)]
    eidx = jnp.clip(base + within, 0, graph.indices.shape[0] - 1)
    dst = jnp.where(valid, graph.indices[eidx], n)
    return EdgeBatch(slot=slot, src=src, dst=dst, valid=valid, total=total,
                     overflow=total > cap_e)


def pack_unique(cands: jnp.ndarray, keep: jnp.ndarray, n: int,
                cap_out: int, backend: str = "xla") -> Frontier:
    """Filter + dedupe candidate vertex ids into a fresh frontier.

    ``cands`` may contain duplicates and sentinel entries; ``keep`` is the
    predicate mask (evaluated by the caller, e.g. ``p[v] >= d(v)*eps``).
    Sort → adjacent-duplicate mask → prefix-sum compaction: O(C log C) work,
    O(log C) depth (paper §3's sort+filter).
    """
    x = jnp.where(keep, cands, n).astype(jnp.int32)
    xs = jnp.sort(x)
    first = jnp.concatenate([jnp.array([True]), xs[1:] != xs[:-1]])
    sel = first & (xs < n)
    pos = ops.prefix_sum(sel.astype(jnp.int32), backend=backend) - 1
    count = jnp.sum(sel).astype(jnp.int32)
    out = jnp.full((cap_out,), n, dtype=jnp.int32)
    # drop writes beyond capacity; overflow flag reports the truncation
    out = out.at[jnp.where(sel, pos, cap_out)].set(xs, mode="drop")
    return Frontier(ids=out, count=jnp.minimum(count, cap_out),
                    overflow=count > cap_out)


def scatter_add_dense(vec: jnp.ndarray, idx: jnp.ndarray, vals: jnp.ndarray,
                      valid: jnp.ndarray, backend: str = "xla") -> jnp.ndarray:
    """fetchAdd → scatter-add: accumulate ``vals`` at ``idx`` (masked).

    Deterministic on every backend (XLA scatter-add has a defined combine
    order; the Pallas MXU path preserves it — see :mod:`repro.core.ops`),
    replacing the paper's atomic fetch-and-add.
    """
    return ops.scatter_add(vec, idx, vals, valid, backend=backend)


def scatter_set_dense(vec: jnp.ndarray, idx: jnp.ndarray, vals,
                      valid: jnp.ndarray) -> jnp.ndarray:
    """Masked ``vec.at[idx].set(vals)`` with the shared drop-sentinel
    convention (invalid lanes write nowhere).  Scatter-*set* has no combine,
    so it has no backend axis — this helper exists so driver code stays free
    of raw ``.at[`` sites outside ops.py/frontier.py."""
    safe = jnp.where(valid, idx, vec.shape[0])
    return vec.at[safe].set(jnp.where(valid, vals, jnp.zeros_like(vals)),
                            mode="drop")


def one_hot_f32(x, n: int) -> jnp.ndarray:
    """f32[n] with a single 1.0 at vertex ``x`` — the unit seed mass every
    dense diffusion starts from."""
    return jnp.zeros((n,), jnp.float32).at[x].set(1.0)
