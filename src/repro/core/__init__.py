"""The paper's contribution: parallel local graph clustering.

Four diffusion engines (Nibble, PR-Nibble, HK-PR, rand-HK-PR) + evolving
sets + the Theorem-1 work-efficient parallel sweep cut, all expressed in the
paper's own primitive vocabulary (prefix sum / filter / sort) on fixed-
capacity frontiers — jit/vmap/shard_map-ready.  Sequential references in
:mod:`repro.core.seq`.
"""
from . import ops
from .frontier import (Frontier, EdgeBatch, singleton, expand, pack_unique,
                       next_pow2, scatter_add_dense, scatter_set_dense)
from .sweep import SweepResult, sweep_cut, sweep_cut_dense, sweep_cut_sparse
from .nibble import NibbleResult, nibble, nibble_fixedcap
from .pr_nibble import PRNibbleResult, pr_nibble, pr_nibble_fixedcap
from .pr_nibble_sparse import (PRNibbleSparseResult, PRNibbleSparseState,
                               pr_nibble_sparse, pr_nibble_sparse_fixedcap,
                               pr_nibble_sparse_init, pr_nibble_sparse_round,
                               pr_nibble_sparse_alive)
from .hk_pr import HKPRResult, hk_pr, hk_pr_fixedcap, psis
from .rand_hk_pr import RandHKPRResult, rand_hk_pr, poisson_cdf_table
from .evolving_sets import EvolvingSetsResult, evolving_sets
from .sparsevec import SparseVec, sv_empty, sv_lookup, sv_merge_add
from .batched import (BatchedDiffusionResult, BatchedClusterResult,
                      batched_pr_nibble, batched_hk_pr, batched_cluster,
                      batched_pr_nibble_fixedcap, batched_hk_pr_fixedcap,
                      batched_cluster_fixedcap, batched_sweep_cut)
from .batched_dist import (BatchedDistDiffusionResult, DistLaneState,
                           batched_dist_pr_nibble, batched_cluster_dist,
                           dist_lane_kernels)
from .batched_sparse import (BatchedSparseDiffusionResult,
                             BatchedSparseClusterResult,
                             batched_pr_nibble_sparse, batched_cluster_sparse,
                             batched_pr_nibble_sparse_fixedcap,
                             batched_cluster_sparse_fixedcap,
                             batched_sparse_sweep_cut, sparse_rows_to_dense,
                             sparse_lane_footprint, pick_backend)
from .ncp import NCPResult, ncp, ncp_batch
from . import seq

__all__ = [
    "ops",
    "Frontier", "EdgeBatch", "singleton", "expand", "pack_unique", "next_pow2",
    "scatter_add_dense", "scatter_set_dense",
    "SweepResult", "sweep_cut", "sweep_cut_dense", "sweep_cut_sparse",
    "NibbleResult", "nibble", "nibble_fixedcap",
    "PRNibbleResult", "pr_nibble", "pr_nibble_fixedcap",
    "PRNibbleSparseResult", "PRNibbleSparseState", "pr_nibble_sparse",
    "pr_nibble_sparse_fixedcap", "pr_nibble_sparse_init",
    "pr_nibble_sparse_round", "pr_nibble_sparse_alive",
    "HKPRResult", "hk_pr", "hk_pr_fixedcap", "psis",
    "RandHKPRResult", "rand_hk_pr", "poisson_cdf_table",
    "EvolvingSetsResult", "evolving_sets",
    "SparseVec", "sv_empty", "sv_lookup", "sv_merge_add",
    "BatchedDiffusionResult", "BatchedClusterResult",
    "batched_pr_nibble", "batched_hk_pr", "batched_cluster",
    "batched_pr_nibble_fixedcap", "batched_hk_pr_fixedcap",
    "batched_cluster_fixedcap", "batched_sweep_cut",
    "BatchedDistDiffusionResult", "DistLaneState",
    "batched_dist_pr_nibble", "batched_cluster_dist", "dist_lane_kernels",
    "BatchedSparseDiffusionResult", "BatchedSparseClusterResult",
    "batched_pr_nibble_sparse", "batched_cluster_sparse",
    "batched_pr_nibble_sparse_fixedcap", "batched_cluster_sparse_fixedcap",
    "batched_sparse_sweep_cut", "sparse_rows_to_dense",
    "sparse_lane_footprint", "pick_backend",
    "NCPResult", "ncp", "ncp_batch",
    "seq",
]
