"""Per-arch smoke: reduced same-family config, one train + serve step on CPU,
shape + finiteness assertions (assignment deliverable f)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config, get_config, cell_supported
from repro.models import build_model
from repro.train import AdamWConfig, adamw_init, make_train_step


def _batch_for(cfg, key, b=2, s=32):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.enc_dec:
        batch["frames"] = jax.random.normal(key, (b, cfg.enc_seq, cfg.d_model))
    if cfg.n_modality_tokens:
        batch["frontend_emb"] = jax.random.normal(
            key, (b, cfg.n_modality_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_train_and_decode_step(arch):
    cfg = smoke_config(arch)
    m = build_model(cfg, remat=True)
    key = jax.random.PRNGKey(0)
    params = m.init_fn(key)
    batch = _batch_for(cfg, key)

    # one full train step (fwd + bwd + AdamW)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=1e-3)))
    params, opt, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))

    # one serve step (prefill + decode)
    pre = {k: v for k, v in batch.items() if k != "labels"}
    cache, logits = m.prefill_fn(params, pre)
    assert logits.shape == (2, cfg.vocab)
    tok1, cache = m.decode_fn(params, batch["tokens"][:, :1], cache)
    assert tok1.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(tok1)))


def test_full_configs_match_assignment():
    """Spot-check the exact published numbers from the assignment table."""
    g = get_config("gemma3-27b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab) == (62, 5376, 32, 16, 21504, 262144)
    assert g.layer_pattern.count("attn_local") == 5          # 5:1 pattern
    k = get_config("kimi-k2-1t-a32b")
    assert (k.n_layers, k.d_model, k.n_experts, k.top_k) == (61, 7168, 384, 8)
    assert 0.9e12 < k.param_count() < 1.15e12                # ~1T total
    assert 25e9 < k.active_param_count() < 40e9              # ~a32b
    m = get_config("mamba2-2.7b")
    assert m.layer_pattern == ("mamba2",) and m.ff_kind == "none"
    assert m.ssm_state == 128
    r = get_config("recurrentgemma-2b")
    assert r.layer_pattern == ("rglru", "rglru", "attn_local")
    w = get_config("whisper-medium")
    assert w.enc_dec and w.n_enc_layers == 24 and w.enc_seq == 1500
    v = get_config("phi-3-vision-4.2b")
    assert v.modality == "vision" and v.n_modality_tokens == 576


def test_cell_skip_rules():
    ok, _ = cell_supported("yi-6b", "long_500k")
    assert not ok
    ok, _ = cell_supported("mamba2-2.7b", "long_500k")
    assert ok
    ok, _ = cell_supported("whisper-medium", "long_500k")
    assert not ok
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_supported(a, s)[0]
