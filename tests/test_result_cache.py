"""Versioned seed→result cache (serve/result_cache.py).

The contracts under test, matching docs/algorithms.md guarantee #9:

  * hit / miss / graph-version invalidation — a cached community is served
    only at the version it was computed at; bumping the handle's version
    makes every entry stale at once.
  * bit-identity — a cache hit carries exactly the bits a lane would have
    computed (cluster, φ, counters), and hits never corrupt the cached
    entry (copy-on-get).
  * LRU bounding — the cache holds at most ``capacity`` entries, evicting
    least-recently-used; deadline-missed partials are never admitted.
"""
import numpy as np
import pytest

from repro.serve import (ClusterRequest, ClusterResult, LocalClusterEngine,
                         ResultCache, result_key)

CAPS = dict(cap_f=1 << 9, cap_e=1 << 12, cap_n=1 << 10, sweep_cap_e=1 << 13,
            cap_v=1 << 9)


def _result(seed: int, missed: bool = False) -> ClusterResult:
    return ClusterResult(
        request=ClusterRequest(seed=seed), conductance=0.5, size=2,
        volume=4, support=3, cluster=np.array([seed, seed + 1], np.int32),
        pushes=7, iterations=3, bucket=0, overflow=False,
        deadline_missed=missed)


# ------------------------------------------------------------------ key shape

def test_result_key_versions_and_lane_families():
    req = ClusterRequest(seed=5, alpha=0.01, eps=1e-5)
    k_dense = result_key(req, "dense", graph_version=0)
    # dist lanes produce bit-identical rows to dense lanes (guarantee #7):
    # one cache entry serves both
    assert result_key(req, "dist", graph_version=0) == k_dense
    # sparse lanes run the sparse update order — separate identity class
    assert result_key(req, "sparse", graph_version=0) != k_dense
    # the graph version leads the key: any bump is a wholesale invalidation
    assert result_key(req, "dense", graph_version=1) != k_dense
    # the kernel backend is NOT key material (bit-identical, guarantee #6):
    # the key is derived purely from the request + lane family
    assert result_key(req, "dense", 0) == result_key(req, "dense", 0)


def test_lru_bounds_entries_and_counts_evictions():
    cache = ResultCache(capacity=2)
    for s in (1, 2, 3):
        assert cache.put((s,), _result(s))
    assert len(cache) == 2 and cache.evictions == 1
    assert cache.get((1,)) is None          # oldest evicted
    assert cache.get((3,)) is not None
    # a hit refreshes recency: key 3 survives the next insertion, key 2 dies
    cache.put((4,), _result(4))
    assert cache.get((3,)) is not None and cache.get((2,)) is None
    stats = cache.stats()
    assert stats["entries"] == 2 and stats["evictions"] == 2
    assert stats["hits"] == 2 and stats["misses"] == 2


def test_partials_rejected_and_hits_are_isolated_copies():
    cache = ResultCache(capacity=4)
    assert not cache.put(("p",), _result(9, missed=True))
    assert len(cache) == 0
    cache.put(("k",), _result(1))
    hit = cache.get(("k",), request=ClusterRequest(seed=1, deadline_ms=5.0))
    assert hit.request.deadline_ms == 5.0   # carries the incoming request
    hit.cluster[:] = -1                      # consumer mutates its copy...
    again = cache.get(("k",))
    assert np.array_equal(again.cluster, np.array([1, 2], np.int32))


def test_invalidate_clears():
    cache = ResultCache(capacity=4)
    cache.put(("k",), _result(1))
    cache.invalidate()
    assert len(cache) == 0 and cache.get(("k",)) is None


# ------------------------------------------------------------ engine wiring

def test_engine_cache_hits_bit_identical_and_lane_free(sbm_graph):
    eng = LocalClusterEngine(sbm_graph, batch_slots=4, **CAPS)
    reqs = [ClusterRequest(seed=s, alpha=0.05, eps=1e-4)
            for s in (3, 107, 211, 3)]      # seed 3 repeats
    # run() submits the whole list before draining, so the in-stream
    # duplicate enqueues before its twin completes — all 4 compute
    first = eng.run(reqs)
    injections = eng.stats["injections"]
    again = eng.run(reqs)
    # every repeat resolves from the cache: no lane was ever occupied
    assert eng.stats["injections"] == injections
    assert eng.stats["result_cache_hits"] >= len(reqs)
    for a, b in zip(first, again):
        assert a.conductance == b.conductance and a.size == b.size
        assert a.volume == b.volume and a.support == b.support
        assert a.pushes == b.pushes and a.iterations == b.iterations
        assert np.array_equal(a.cluster, b.cluster)
        assert not b.deadline_missed


def test_graph_version_bump_invalidates(sbm_graph):
    eng = LocalClusterEngine(sbm_graph, batch_slots=4, **CAPS)
    req = ClusterRequest(seed=3, alpha=0.05, eps=1e-4)
    eng.run([req])
    assert eng.cached_result(req) is not None
    eng.handle.bump_version()
    assert eng.cached_result(req) is None   # stale at the new version
    # recomputing at the new version repopulates it
    injections = eng.stats["injections"]
    eng.run([req])
    assert eng.stats["injections"] == injections + 1
    assert eng.cached_result(req) is not None


def test_cache_disabled_recomputes(sbm_graph):
    eng = LocalClusterEngine(sbm_graph, batch_slots=4, result_cache=0,
                             **CAPS)
    assert eng.result_cache is None
    req = ClusterRequest(seed=3, alpha=0.05, eps=1e-4)
    eng.run([req])
    injections = eng.stats["injections"]
    eng.run([req])
    assert eng.stats["injections"] == injections + 1   # really recomputed


def test_shared_cache_instance_across_engines(sbm_graph):
    shared = ResultCache(capacity=64)
    a = LocalClusterEngine(sbm_graph, batch_slots=4, result_cache=shared,
                           **CAPS)
    b = LocalClusterEngine(sbm_graph, batch_slots=4, result_cache=shared,
                           **CAPS)
    req = ClusterRequest(seed=3, alpha=0.05, eps=1e-4)
    ra = a.run([req])[0]
    # engine b never computed anything, yet serves a's converged answer
    rb = b.cached_result(req)
    assert rb is not None and rb.conductance == ra.conductance
    assert np.array_equal(rb.cluster, ra.cluster)
