"""Pipeline parallelism: pp_forward == sequential layer application
(subprocess with 4 host devices)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.models.pipeline import pp_forward

mesh = jax.make_mesh((4,), ("pipe",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
L, D, M, MB = 8, 16, 6, 4              # 8 layers, 6 microbatches of 4
params = {"w": jnp.asarray(rng.standard_normal((L, D, D)) * 0.3, jnp.float32),
          "b": jnp.asarray(rng.standard_normal((L, D)) * 0.1, jnp.float32)}
x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

def block_fn(lp, h):
    return jnp.tanh(h @ lp["w"] + lp["b"])

out = pp_forward(mesh, "pipe", params, x, block_fn)

# sequential oracle
ref = x
for i in range(L):
    lp = jax.tree.map(lambda a: a[i], params)
    ref = block_fn(lp, ref)
print("RESULT:" + json.dumps({
    "maxdiff": float(jnp.abs(out - ref).max()),
    "shape_ok": list(out.shape) == [M, MB, D],
}))
"""


@pytest.mark.slow
def test_pp_forward_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["shape_ok"]
    assert out["maxdiff"] < 1e-5
