"""Elastic scaling + failure handling.

``reshard_state``: move a (params, opt_state) bundle onto a different mesh —
the core of both planned resizes (512→384 chips) and unplanned mesh shrink
after node loss.  Arrays are global in the checkpoint format, so resharding
is a device_put with the new mesh's NamedShardings; for data-parallel-only
dimension changes no value movement beyond slicing occurs.

``Heartbeat``: coordinator-side liveness file protocol.  Every host touches
its heartbeat file each step; the coordinator declares a host dead after
``timeout`` and triggers: (1) restore from the last committed checkpoint,
(2) re-form the mesh from survivors, (3) resume — the deterministic data
pipeline (data/pipeline.py) makes the resumed stream exact.
"""
from __future__ import annotations

import os
import time
from typing import Optional

import jax
from jax.sharding import NamedSharding

__all__ = ["reshard_state", "Heartbeat"]


def reshard_state(state, new_mesh, spec_tree):
    """device_put a pytree onto a new mesh with the given PartitionSpecs."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        state, spec_tree)


class Heartbeat:
    def __init__(self, directory: str, host_id: int, timeout: float = 60.0):
        self.dir = directory
        self.host_id = host_id
        self.timeout = timeout
        os.makedirs(directory, exist_ok=True)

    def _path(self, host: int) -> str:
        return os.path.join(self.dir, f"host_{host:05d}.hb")

    def beat(self):
        with open(self._path(self.host_id), "w") as f:
            f.write(str(time.time()))

    def alive_hosts(self, num_hosts: int) -> list:
        now = time.time()
        out = []
        for h in range(num_hosts):
            try:
                with open(self._path(h)) as f:
                    t = float(f.read().strip())
                if now - t < self.timeout:
                    out.append(h)
            except (FileNotFoundError, ValueError):
                pass
        return out

    def dead_hosts(self, num_hosts: int) -> list:
        alive = set(self.alive_hosts(num_hosts))
        return [h for h in range(num_hosts) if h not in alive]
