"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Real-Gated Linear Recurrent Unit:
    r_t = σ(W_a x_t + b_a)            (recurrence gate)
    i_t = σ(W_x x_t + b_x)            (input gate)
    a_t = a^(c·r_t)   with a = σ(Λ),  c = 8
    h_t = a_t ⊙ h_{t−1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The linear recurrence runs as a ``jax.lax.associative_scan`` over (a, b)
pairs — O(log S) depth on TPU.  The full residual block is the Griffin
recurrent block: in-proj → short conv1d → RG-LRU → gated out-proj.

Decode carries (h state [B, W], conv tail [B, conv−1, W]) in the cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

__all__ = ["rglru_init", "rglru_apply", "rglru_decode_step", "rglru_state_shapes"]

_C = 8.0


def rglru_init(key, cfg, dtype="bfloat16"):
    d = cfg.d_model
    w = cfg.d_ff_rnn
    ks = jax.random.split(key, 6)
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    return {
        "in_x": dense_init(ks[0], (d,), (w,), dtype),
        "in_gate": dense_init(ks[1], (d,), (w,), dtype),
        "conv_w": (jax.random.normal(ks[5], (cfg.rglru_conv_width, w),
                                     jnp.float32) * 0.1).astype(dtype),
        "w_a": dense_init(ks[2], (w,), (w,), dtype),
        "w_i": dense_init(ks[3], (w,), (w,), dtype),
        "lam": jnp.log(u / (1.0 - u)),   # Λ with a = σ(Λ) ∈ (0.9, 0.999)
        "out": dense_init(jax.random.fold_in(key, 7), (w,), (d,), dtype),
    }


def rglru_state_shapes(cfg, batch):
    w = cfg.d_ff_rnn
    return {"h": (batch, w), "conv": (batch, cfg.rglru_conv_width - 1, w)}


def _conv1d(x, conv_w):
    """Causal depthwise conv along S: x [B,S,W], conv_w [K,W]."""
    k = conv_w.shape[0]
    pads = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pads[:, i: i + x.shape[1], :] * conv_w[i][None, None, :]
    return out


def _gates(params, xb):
    r = jax.nn.sigmoid(dense(params["w_a"], xb, "bsw,wv->bsv").astype(jnp.float32))
    i = jax.nn.sigmoid(dense(params["w_i"], xb, "bsw,wv->bsv").astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(params["lam"])     # log a_t ≤ 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * xb.astype(jnp.float32))


def rglru_apply(params, u, cfg, return_state: bool = False):
    """u: [B,S,D] -> [B,S,D] (full Griffin recurrent block).

    With ``return_state`` also returns {h: [B,W], conv: [B,K−1,W]} — the
    decode continuation state after the sequence."""
    xb_raw = dense(params["in_x"], u, "bsd,dw->bsw")
    gate = dense(params["in_gate"], u, "bsd,dw->bsw")
    xb = _conv1d(xb_raw, params["conv_w"])
    a, b = _gates(params, xb)                            # [B,S,W] f32

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h * jax.nn.gelu(gate.astype(jnp.float32))
    out = dense(params["out"], y.astype(u.dtype), "bsw,wd->bsd")
    if return_state:
        k = cfg.rglru_conv_width
        conv_tail = xb_raw[:, -(k - 1):, :]
        pad = (k - 1) - conv_tail.shape[1]
        if pad > 0:
            conv_tail = jnp.pad(conv_tail, ((0, 0), (pad, 0), (0, 0)))
        return out, {"h": h[:, -1], "conv": conv_tail}
    return out


def rglru_decode_step(params, u, state, cfg):
    """u: [B,1,D]; state: {h: [B,W], conv: [B,K−1,W]} → (y, new state)."""
    xb = dense(params["in_x"], u, "bsd,dw->bsw")         # [B,1,W]
    gate = dense(params["in_gate"], u, "bsd,dw->bsw")
    k = cfg.rglru_conv_width
    hist = jnp.concatenate([state["conv"], xb.astype(state["conv"].dtype)], 1)
    conv_out = jnp.einsum("bkw,kw->bw", hist.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xb1 = conv_out[:, None, :].astype(u.dtype)
    a, b = _gates(params, xb1)                           # [B,1,W]
    h = a[:, 0] * state["h"] + b[:, 0]
    y = h[:, None, :] * jax.nn.gelu(gate.astype(jnp.float32))
    out = dense(params["out"], y.astype(u.dtype), "bsw,wd->bsd")
    return out, {"h": h, "conv": hist[:, 1:, :]}
