"""Op-layer micro-benchmarks: the four hot primitives, per backend.

Times each ``repro.core.ops`` op under ``backend="xla"`` and
``backend="pallas"`` on representative driver shapes (scatter batches the
size of an edge workspace, merges the size of a SparseVec round, scans the
size of a sweep grid).  On CPU the Pallas backend runs in interpret mode —
wall time there measures the *dispatch pipeline*, not the kernel (the TPU
story lives in the roofline docs) — but every row doubles as a smoke-level
correctness probe: each pallas timing asserts bitwise agreement with the
xla reference before it is reported, so the CI ``--smoke`` gate exercises
the full kernel path on every run.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops
from repro.kernels import ops as kops
from .common import get_graph, emit, timeit


def _interp_tag() -> str:
    """";interpret=true" on non-TPU hosts, where Pallas runs in interpret
    mode: those 10–18× pallas-vs-xla slowdowns measure the interpreter, not
    hardware, and the artifact must say so."""
    return ";interpret=true" if jax.default_backend() != "tpu" else ""


def _assert_bitwise(a, b, what):
    an = [np.atleast_1d(np.asarray(t))
          for t in (a if isinstance(a, tuple) else (a,))]
    bn = [np.atleast_1d(np.asarray(t))
          for t in (b if isinstance(b, tuple) else (b,))]
    for x, y in zip(an, bn):
        if not np.array_equal(x.view(np.uint8), y.view(np.uint8)):
            raise AssertionError(f"{what}: pallas != xla")


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    n = 1 << 12 if smoke else 1 << 16
    m = 1 << 13 if smoke else 1 << 18

    # scatter_add — the fetchAdd batch of one push round
    vec = jnp.asarray(rng.random(n), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    vals = jnp.asarray(rng.random(m), jnp.float32)
    valid = jnp.asarray(rng.random(m) < 0.9)
    outs = {}
    for backend in ("xla", "pallas"):
        us, outs[backend] = timeit(ops.scatter_add, vec, idx, vals, valid,
                                   backend=backend, prime=not smoke)
        tag = _interp_tag() if backend == "pallas" else ""
        emit(f"ops/scatter_add_{backend}", us, f"n={n};m={m}{tag}")
    _assert_bitwise(outs["xla"], outs["pallas"], "scatter_add")

    # segment_merge — one sv_merge_add of a sparse round
    cap = 1 << 10 if smoke else 1 << 12
    ids = jnp.asarray(rng.integers(0, n + 1, cap + m // 4), jnp.int32)
    mvals = jnp.asarray(rng.random(cap + m // 4), jnp.float32)
    for backend in ("xla", "pallas"):
        us, outs[backend] = timeit(ops.segment_merge, ids, mvals, n, cap,
                                   backend=backend, prime=not smoke)
        tag = _interp_tag() if backend == "pallas" else ""
        emit(f"ops/segment_merge_{backend}", us,
             f"stream={int(ids.shape[0])};cap={cap}{tag}")
    _assert_bitwise(outs["xla"], outs["pallas"], "segment_merge")

    # prefix_sum — the sweep's int32 difference-array scan
    x = jnp.asarray(rng.integers(-3, 4, m), jnp.int32)
    for backend in ("xla", "pallas"):
        us, outs[backend] = timeit(ops.prefix_sum, x, backend=backend,
                                   prime=not smoke)
        tag = _interp_tag() if backend == "pallas" else ""
        emit(f"ops/prefix_sum_i32_{backend}", us, f"n={m}{tag}")
    _assert_bitwise(outs["xla"], outs["pallas"], "prefix_sum")

    # diffusion_spmv — saturated round on the hybrid ELL layout (allclose op)
    g = get_graph("sbm-planted" if smoke else "randLocal-50k")
    nbr, wgt, es, ed, ew, n_pad, W = kops.pack_banded_ell(g, halo=2)
    p = jnp.asarray(rng.random(n_pad), jnp.float32)
    for backend in ("xla", "pallas"):
        us, outs[backend] = timeit(ops.diffusion_spmv, nbr, wgt, es, ed, ew,
                                   p, halo=2, backend=backend,
                                   prime=not smoke)
        tag = _interp_tag() if backend == "pallas" else ""
        emit(f"ops/diffusion_spmv_{backend}", us, f"n={n_pad};W={W}{tag}")
    np.testing.assert_allclose(np.asarray(outs["xla"]),
                               np.asarray(outs["pallas"]), rtol=1e-5,
                               atol=1e-6)
    # artifact-level flag, mirrored per-row above: BENCH_ops.json numbers
    # from an interpret-mode host must never be read as TPU numbers
    return dict(default_backend=jax.default_backend(),
                interpret=jax.default_backend() != "tpu")


if __name__ == "__main__":
    run()
