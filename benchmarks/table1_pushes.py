"""Table 1 reproduction: parallel vs sequential PR-Nibble push counts.

Paper claim (C1): parallel pushes exceed sequential by ≤1.6× (usually much
less) and iterations ≪ pushes.  Paper params: α=0.01, ε=1e-7 (we also run a
coarser ε so small graphs produce meaningful frontiers).
"""
import numpy as np

from repro.core import pr_nibble, seq
from .common import GRAPH_SUITE, get_graph, emit, timeit


def run(alpha=0.01, eps=1e-7, smoke: bool = False):
    graphs = ["sbm-planted"] if smoke else list(GRAPH_SUITE)
    if smoke:
        eps = 1e-5
    for name in graphs:
        g = get_graph(name)
        seed = 5 if name == "sbm-planted" else int(np.argmax(np.asarray(g.deg)))
        us, res = timeit(pr_nibble, g, seed, eps, alpha, repeats=1)
        ref = seq.seq_pr_nibble(g, seed, eps, alpha, optimized=True)
        ratio = int(res.pushes) / max(ref["pushes"], 1)
        emit(f"table1/{name}/parallel_pushes", us,
             f"pushes={int(res.pushes)};iters={int(res.iterations)}")
        emit(f"table1/{name}/sequential_pushes", 0.0,
             f"pushes={ref['pushes']};ratio={ratio:.3f}")


if __name__ == "__main__":
    run()
