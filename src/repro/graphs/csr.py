"""Compressed-sparse-row graph container used by every layer of the framework.

The paper (§3) studies undirected, unweighted graphs with vertices indexed
``0..n-1``.  We store the symmetrized adjacency in CSR form:

  * ``indptr``  : int32[n+1]   row offsets
  * ``indices`` : int32[2m]    neighbor lists (both directions of every edge)
  * ``deg``     : int32[n]     degrees (== indptr[1:] - indptr[:-1])

Construction is host-side numpy (it happens once, at load time); the arrays are
then moved to device and treated as read-only.  All per-query work is done by
the fixed-capacity frontier machinery in :mod:`repro.core.frontier`, which only
*gathers* from these arrays — the TPU-native analogue of Ligra's EdgeMap.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["CSRGraph", "build_csr", "from_edge_list", "load_edge_file", "ell_pack"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Immutable device-resident CSR graph (undirected, unweighted)."""

    indptr: jnp.ndarray   # int32[n+1]
    indices: jnp.ndarray  # int32[2m]  (padded tail allowed; see `num_directed`)
    deg: jnp.ndarray      # int32[n]
    n: int                # static number of vertices
    m: int                # static number of *undirected* edges

    # -- pytree protocol (n, m static so the graph can cross jit boundaries) --
    def tree_flatten(self):
        return (self.indptr, self.indices, self.deg), (self.n, self.m)

    @classmethod
    def tree_unflatten(cls, aux, children):
        indptr, indices, deg = children
        n, m = aux
        return cls(indptr=indptr, indices=indices, deg=deg, n=n, m=m)

    # -- convenience ---------------------------------------------------------
    @property
    def num_directed(self) -> int:
        return 2 * self.m

    @property
    def total_volume(self) -> int:
        """vol(V) = 2m for an undirected graph."""
        return 2 * self.m

    def degree(self, v) -> jnp.ndarray:
        return self.deg[v]

    def neighbors_np(self, v: int) -> np.ndarray:
        """Host-side neighbor list (tests / sequential references)."""
        ip = np.asarray(self.indptr)
        idx = np.asarray(self.indices)
        return idx[ip[v]: ip[v + 1]]

    def to_numpy(self) -> "CSRGraph":
        return CSRGraph(
            indptr=np.asarray(self.indptr),
            indices=np.asarray(self.indices),
            deg=np.asarray(self.deg),
            n=self.n,
            m=self.m,
        )


def build_csr(edges: np.ndarray, n: int) -> CSRGraph:
    """Build a symmetric CSR from an ``(e, 2)`` int array of undirected edges.

    Self-loops and duplicate edges are removed, matching the paper's
    preprocessing ("We removed all self and duplicate edges from the graphs").
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # drop self loops
    edges = edges[edges[:, 0] != edges[:, 1]]
    # canonical order then dedupe
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    key = lo * n + hi
    _, first = np.unique(key, return_index=True)
    lo, hi = lo[first], hi[first]
    m = lo.shape[0]
    # symmetrize
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    deg = np.bincount(src, minlength=n).astype(np.int32)
    indptr = np.zeros(n + 1, dtype=np.int32)
    np.cumsum(deg, out=indptr[1:])
    return CSRGraph(
        indptr=jnp.asarray(indptr),
        indices=jnp.asarray(dst.astype(np.int32)),
        deg=jnp.asarray(deg),
        n=int(n),
        m=int(m),
    )


def from_edge_list(src, dst, n: Optional[int] = None) -> CSRGraph:
    src = np.asarray(src)
    dst = np.asarray(dst)
    if n is None:
        n = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
    return build_csr(np.stack([src, dst], axis=1), n)


def load_edge_file(path: str, n: Optional[int] = None) -> CSRGraph:
    """Load a whitespace edge list (SNAP format; '#' comments ignored)."""
    edges = np.loadtxt(path, dtype=np.int64, comments="#").reshape(-1, 2)
    if n is None:
        n = int(edges.max() + 1)
    return build_csr(edges, n)


def ell_pack(graph: CSRGraph, width: Optional[int] = None):
    """ELLPACK view: ``nbr[n, width]`` padded with ``n`` (sentinel), plus mask.

    Used by the Pallas push kernel: a rectangular layout turns the irregular
    CSR gather into dense VMEM tiles.  ``width`` defaults to the max degree —
    callers working with power-law graphs should pass an explicit width and
    route overflow rows through the CSR path (`hybrid` mode in ops.py).
    """
    g = graph.to_numpy()
    w = int(g.deg.max()) if width is None else int(width)
    nbr = np.full((g.n, w), g.n, dtype=np.int32)
    for v in range(g.n):
        row = g.indices[g.indptr[v]: g.indptr[v + 1]][:w]
        nbr[v, : row.shape[0]] = row
    return jnp.asarray(nbr), w
