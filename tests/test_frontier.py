"""Property tests for the frontier machinery + sort-merge sparse sets
(hypothesis) — the paper's §3 primitives."""
import numpy as np
import pytest
import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property suite needs hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.frontier import Frontier, expand, pack_unique, singleton
from repro.core.sparsevec import (sv_empty, sv_from_pairs, sv_lookup,
                                  sv_merge_add, sv_update_existing)
from repro.graphs import rand_local

GRAPH = rand_local(300, degree=4, seed=7)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 299), min_size=1, max_size=40, unique=True))
def test_expand_enumerates_exactly_adjacency(ids):
    g = GRAPH.to_numpy()
    cap_f, cap_e = 64, 4096
    f_ids = np.full(cap_f, GRAPH.n, np.int32)
    f_ids[: len(ids)] = sorted(ids)
    f = Frontier(ids=jnp.asarray(f_ids), count=jnp.asarray(len(ids), jnp.int32),
                 overflow=jnp.asarray(False))
    eb = expand(GRAPH, f, cap_e)
    got = sorted(zip(np.asarray(eb.src)[np.asarray(eb.valid)],
                     np.asarray(eb.dst)[np.asarray(eb.valid)]))
    want = sorted((v, int(w)) for v in sorted(ids)
                  for w in g.indices[g.indptr[v]: g.indptr[v + 1]])
    assert got == want
    assert int(eb.total) == len(want)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=1, max_size=200),
       st.integers(0, 2**31 - 1))
def test_pack_unique_is_sorted_set(cands, seed):
    rng = np.random.default_rng(seed)
    keep = rng.random(len(cands)) < 0.7
    arr = jnp.asarray(np.asarray(cands, np.int32))
    f = pack_unique(arr, jnp.asarray(keep), n=100, cap_out=128)
    got = np.asarray(f.ids)[: int(f.count)].tolist()
    want = sorted({c for c, k in zip(cands, keep) if k})
    assert got == want
    assert not bool(f.overflow)


def test_pack_unique_overflow_flag():
    cands = jnp.arange(100, dtype=jnp.int32)
    f = pack_unique(cands, jnp.ones(100, bool), n=1000, cap_out=16)
    assert bool(f.overflow)
    assert int(f.count) == 16


@settings(max_examples=25, deadline=None)
@given(st.dictionaries(st.integers(0, 63), st.floats(0.01, 10.0),
                       max_size=20),
       st.lists(st.tuples(st.integers(0, 63), st.floats(0.01, 5.0)),
                max_size=30))
def test_sparsevec_merge_add_matches_dict(base, updates):
    n, cap = 64, 128
    ids = np.fromiter(base.keys(), np.int32, len(base))
    vals = np.fromiter(base.values(), np.float32, len(base))
    pad = cap - len(ids)
    sv = sv_from_pairs(jnp.asarray(np.pad(ids, (0, pad))),
                       jnp.asarray(np.pad(vals, (0, pad))),
                       jnp.arange(cap) < len(ids), cap, n)
    uid = np.asarray([u[0] for u in updates] + [0], np.int32)
    uval = np.asarray([u[1] for u in updates] + [0.0], np.float32)
    uvalid = jnp.arange(uid.shape[0]) < len(updates)
    out = sv_merge_add(sv, jnp.asarray(uid), jnp.asarray(uval), uvalid, n)

    want = dict(base)
    for k, v in updates:
        want[k] = want.get(k, 0.0) + v
    got = {int(i): float(v) for i, v in
           zip(np.asarray(out.ids)[: int(out.count)],
               np.asarray(out.vals)[: int(out.count)])}
    assert set(got) == set(want)
    for k in want:
        np.testing.assert_allclose(got[k], want[k], rtol=1e-4)
    # ids stay sorted
    sorted_ids = np.asarray(out.ids)[: int(out.count)]
    assert np.all(np.diff(sorted_ids) > 0)


def test_sparsevec_lookup_missing_is_zero():
    sv = sv_empty(16, 100)
    sv = sv_merge_add(sv, jnp.asarray([3, 7], jnp.int32),
                      jnp.asarray([1.5, 2.5], jnp.float32),
                      jnp.asarray([True, True]), 100)
    q = sv_lookup(sv, jnp.asarray([3, 4, 7, 99], jnp.int32), 100)
    np.testing.assert_allclose(np.asarray(q), [1.5, 0.0, 2.5, 0.0])


def test_sparsevec_update_existing():
    sv = sv_from_pairs(jnp.asarray([1, 5, 9, 0], jnp.int32),
                       jnp.asarray([1., 2., 3., 0.], jnp.float32),
                       jnp.asarray([True, True, True, False]), 8, 100)
    sv = sv_update_existing(sv, jnp.asarray([5, 9], jnp.int32),
                            jnp.asarray([0.0, 7.0], jnp.float32),
                            jnp.asarray([True, True]))
    q = sv_lookup(sv, jnp.asarray([1, 5, 9], jnp.int32), 100)
    np.testing.assert_allclose(np.asarray(q), [1.0, 0.0, 7.0])
