"""Version-compat shims for the sharding APIs the distributed engine uses.

The repo targets the modern spelling (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh`` with ``axis_types``) but must also run on the jax builds
baked into CPU CI containers, where ``shard_map`` still lives under
``jax.experimental`` (flag spelled ``check_rep``) and ``AxisType`` does not
exist yet.  Every shard_map/mesh construction in the repo goes through these
two helpers so the fallback lives in exactly one place.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh"]


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax version."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
