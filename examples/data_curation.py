"""Paper ↔ LM bridge: cluster-balanced data curation.

A production coupling of local graph clustering with LM training: build a
document-similarity graph, peel local clusters with PR-Nibble (the paper's
interactive engine, batched), and sample training batches balanced across
clusters instead of uniformly — the dedup/diversity curation pattern.

    PYTHONPATH=src python examples/data_curation.py
"""
import numpy as np

from repro.graphs import sbm
from repro.core import pr_nibble, sweep_cut_dense

# --- stand-in corpus: 600 "documents" with 6 latent topics ------------------
# similarity graph = SBM (in production: kNN over embeddings)
graph = sbm(k=6, size=100, p_in=0.12, p_out=0.003, seed=7)
n_docs = graph.n
rng = np.random.default_rng(0)

# --- discover clusters by seeding PR-Nibble on uncovered documents ---------
assignment = np.full(n_docs, -1)
cluster_id = 0
deg = np.asarray(graph.deg)
while (assignment < 0).sum() > n_docs * 0.05 and cluster_id < 12:
    uncovered = np.flatnonzero(assignment < 0)
    seed = int(uncovered[np.argmax(deg[uncovered])])
    diff = pr_nibble(graph, seed, eps=1e-7, alpha=0.01)
    sw = sweep_cut_dense(graph, diff.p, 1 << 11, 1 << 17)
    members = np.asarray(sw.cluster())[: int(sw.best_size)]
    members = members[assignment[members] < 0]
    if members.size < 5:
        assignment[seed] = cluster_id  # singleton fallback
    else:
        assignment[members] = cluster_id
    print(f"cluster {cluster_id}: {members.size:4d} docs "
          f"(φ={float(sw.best_conductance):.4f})")
    cluster_id += 1
assignment[assignment < 0] = cluster_id  # leftovers bucket

# --- cluster-balanced sampling vs uniform ----------------------------------
clusters = [np.flatnonzero(assignment == c) for c in range(cluster_id + 1)
            if (assignment == c).any()]
batch = 64
uniform = rng.choice(n_docs, size=batch)
balanced = np.concatenate([
    rng.choice(c, size=max(batch // len(clusters), 1)) for c in clusters])[:batch]

def spread(sample):
    counts = np.bincount(assignment[sample], minlength=cluster_id + 1)
    probs = counts[counts > 0] / counts.sum()
    return float(-(probs * np.log(probs)).sum())

print(f"\nbatch topic-entropy: uniform={spread(uniform):.3f}  "
      f"cluster-balanced={spread(balanced):.3f} "
      f"(max={np.log(len(clusters)):.3f})")
print("cluster-balanced batches feed repro.data pipelines via doc-id lists.")
