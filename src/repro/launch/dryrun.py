import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing module: jax locks the device count on
# first init.  Only the dry-run sees 512 placeholder devices.

"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

For every cell this
  1. builds the FULL-size model abstractly (jax.eval_shape — no allocation),
  2. jits the step (train_step incl. optimizer / prefill / decode) with
     explicit in/out shardings on the production mesh,
  3. lowers + compiles, prints memory_analysis / cost_analysis,
  4. extracts the three roofline terms (launch/roofline.py) and writes
     experiments/dryrun/<arch>__<shape>__<mesh>[__<variant>].json.

Sharding bugs, compile-time OOM, and unsupported collectives fail HERE —
that is the point.  Results are cached by cell key; --force recomputes.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --graph        # paper-engine cells
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import SHAPES
from repro.configs import ARCHS, get_config, cell_supported
from repro.models import build_model, batch_axes
from repro.models.model import make_batch_specs
from repro.train import AdamWConfig, make_train_step, adamw_init
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_report, cost_analysis_dict, HW

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# hillclimb variants (see EXPERIMENTS.md §Perf)
VARIANTS = ("base", "remat_none", "zero1", "seqshard", "int8grads",
            "fsdp", "flat_batch", "moe_local", "fsdp_zero1", "combined")


def _shard(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def _batch_shardings(batch_sds, bspec, mesh):
    def one(path, leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(bspec, *(None,) * (nd - 1)))
    return jax.tree_util.tree_map_with_path(one, batch_sds)


def lower_cell(arch: str, shape_name: str, mesh, variant: str = "base"):
    """Returns (lowered, compiled, meta) for one cell."""
    cfg = get_config(arch)
    if variant in ("moe_local", "combined"):
        import dataclasses as _dc
        cfg = _dc.replace(cfg, moe_per_row=True)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size
    remat = shape.kind == "train" and variant != "remat_none"
    model = build_model(cfg, remat=remat)
    bspec = batch_axes(shape.global_batch, mesh)
    if variant == "flat_batch" and bspec is not None:
        # fold model axis into batch sharding when batch allows (pure DP)
        pass

    params_sds = model.abstract_params()
    pspecs = model.param_partition_specs(mesh)
    if variant in ("fsdp", "fsdp_zero1", "combined"):
        # ZeRO-3-flavored: additionally shard params over data on their
        # largest replicated dim
        from repro.train.optimizer import zero_shard_specs
        pspecs = zero_shard_specs(pspecs, params_sds, mesh, axis="data")
    pshard = _shard(mesh, pspecs)
    model_flops_coef = 6.0 if shape.kind == "train" else 2.0
    n_active = cfg.active_param_count()
    tokens_global = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                          else 1)
    model_flops = model_flops_coef * n_active * tokens_global

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        ospecs = type(opt_sds)(mu=pspecs, nu=pspecs, count=P())
        if variant in ("zero1", "fsdp", "fsdp_zero1", "combined"):
            from repro.train.optimizer import zero_shard_specs
            ospecs = type(opt_sds)(
                mu=zero_shard_specs(pspecs, params_sds, mesh, "data"),
                nu=zero_shard_specs(pspecs, params_sds, mesh, "data"),
                count=P())
        oshard = _shard(mesh, ospecs)
        batch_sds = make_batch_specs(cfg, shape)
        bshard = _batch_shardings(batch_sds, bspec, mesh)
        ocfg = AdamWConfig(
            compress_grads="int8" if variant == "int8grads" else None)
        step = make_train_step(model, ocfg)
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_sds = make_batch_specs(cfg, shape)
        bshard = _batch_shardings(batch_sds, bspec, mesh)
        fn = jax.jit(model.prefill_fn, in_shardings=(pshard, bshard))
        lowered = fn.lower(params_sds, batch_sds)
    else:  # decode
        cache_sds = model.abstract_cache(shape.global_batch, shape.seq_len)
        cspecs = model.cache_partition_specs(shape.global_batch,
                                             shape.seq_len, mesh)
        if variant == "seqshard":
            cspecs = _seqshard_cache(cspecs, cache_sds, mesh)
        cshard = _shard(mesh, cspecs)
        tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tshard = NamedSharding(mesh, P(bspec, None))
        fn = jax.jit(model.decode_fn, in_shardings=(pshard, tshard, cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
        lowered = fn.lower(params_sds, tok_sds, cache_sds)

    meta = dict(arch=arch, shape=shape_name, chips=chips, variant=variant,
                model_flops=model_flops, n_active_params=n_active,
                n_total_params=cfg.param_count(),
                tokens_per_step=tokens_global, kind=shape.kind)
    return lowered, meta


def _seqshard_cache(cspecs, cache_sds, mesh):
    """Hillclimb variant: shard the KV-cache sequence dim over `data`
    (long-context decode with batch=1 — see §Perf)."""
    def one(spec, leaf):
        t = tuple(spec)
        shape = leaf.shape
        if len(shape) >= 4 and len(t) == len(shape):
            # k/v caches: [..., B, S, Kv|None, Dh]; seq dim = -3
            d = len(shape) - 3
            if shape[d] % mesh.shape["data"] == 0 and t[d] is None \
                    and shape[d] >= 4096:
                t = t[:d] + ("data",) + t[d + 1:]
        return P(*t)
    return jax.tree.map(one, cspecs, cache_sds)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             variant: str = "base", force: bool = False, out_dir=None):
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    key = f"{arch}__{shape_name}__{mesh_name}" + \
        (f"__{variant}" if variant != "base" else "")
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        rec = dict(cell=key, skipped=True, reason=reason)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[skip] {key}: {reason}", flush=True)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    t0 = time.time()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh, variant)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        print(f"[{key}] memory_analysis: {mem}", flush=True)
        ca = cost_analysis_dict(compiled)
        print(f"[{key}] cost: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}", flush=True)
        rep = roofline_report(compiled, chips=meta["chips"],
                              model_flops=meta["model_flops"])
        for dup in ("num_chips", "model_flops"):
            rep.pop(dup, None)
        rec = dict(cell=key, skipped=False, **meta, **rep,
                   lower_s=round(t_lower, 2), compile_s=round(t_compile, 2))
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[ok] {key}: bottleneck={rep['bottleneck']} "
              f"compute={rep['compute_s']*1e3:.2f}ms "
              f"mem={rep['memory_s']*1e3:.2f}ms "
              f"coll={rep['collective_s']*1e3:.2f}ms "
              f"roofline_frac={rep.get('roofline_fraction', 0):.3f} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
        return rec
    except Exception as e:
        rec = dict(cell=key, skipped=False, error=str(e)[:2000],
                   traceback=traceback.format_exc()[-4000:])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[FAIL] {key}: {e}", flush=True)
        return rec


def run_graph_cells(mesh_name: str, force: bool = False, out_dir=None,
                    exchange: str = "a2a"):
    """Dry-run the paper engine itself: distributed PR-Nibble on the
    production mesh (vertex-partitioned; data axis = 256/512-way).
    ``exchange``: "a2a" (bucketed, locality-aware) or "psum" (naive dense
    all-reduce baseline) — the §Perf comparison for the paper's technique."""
    out_dir = out_dir or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    key = f"graph_pr_nibble__n64M__{mesh_name}__{exchange}"
    path = os.path.join(out_dir, key + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    from repro.core.distributed import build_dist_pr_nibble
    D = mesh.devices.size
    rows_per = (1 << 26) // D          # 64M-vertex graph
    nnz_per = rows_per * 16            # avg degree 16
    from repro.compat import make_mesh
    make = build_dist_pr_nibble(make_mesh((D,), ("data",)), "data",
                                exchange=exchange)
    fn = jax.jit(make(rows_per, 1 << 14, 1 << 18, 1 << 12))
    sds = (
        jax.ShapeDtypeStruct((D, rows_per + 1), jnp.int32),
        jax.ShapeDtypeStruct((D, nnz_per), jnp.int32),
        jax.ShapeDtypeStruct((D, rows_per), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    t0 = time.time()
    try:
        lowered = fn.lower(*sds)
        compiled = lowered.compile()
        print(f"[{key}] memory: {compiled.memory_analysis()}", flush=True)
        rep = roofline_report(compiled, chips=D, model_flops=None)
        rec = dict(cell=key, skipped=False, chips=D, **rep,
                   compile_s=round(time.time() - t0, 2))
    except Exception as e:
        rec = dict(cell=key, skipped=False, error=str(e)[:2000])
        print(f"[FAIL] {key}: {e}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--graph", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.graph:
        for m in meshes:
            for ex in ("a2a", "psum"):
                run_graph_cells(m, args.force, args.out, exchange=ex)
        return
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all, or --arch/--shape")
    for m in meshes:
        for a in archs:
            for s in shapes:
                run_cell(a, s, m, args.variant, args.force, args.out)


if __name__ == "__main__":
    main()
