"""Batched sparse multi-seed local clustering — memory-bounded many-seed serving.

The dense batched engine (core/batched.py) answers B queries in one dispatch
but materializes B × f32[n] state vectors: on a billion-edge graph a 64-seed
batch is 256 GB of ``p``/``r`` before the first push.  That loses exactly the
locality the paper parallelizes — local algorithms do work (and, in
Spielman–Teng's original formulation, hold memory) proportional to the
*cluster*, not the graph.  This module restores that profile under vmap:
every lane carries only a compacted sparse ``(ids, vals)`` pair of capacity
``cap_v`` (the lane's K), a frontier of capacity ``cap_f``, and an edge
workspace of capacity ``cap_e`` — per-lane live values are O(K), independent
of n.

Layers:

  * :func:`batched_pr_nibble_sparse_fixedcap` — vmap of the single-seed
    sparse kernel: seeds[B] with per-seed (ε, α), shared static
    ``(cap_f, cap_e, cap_v)``.  XLA's while-loop batching masks finished
    lanes, so each lane's trajectory is identical to the single-seed run.
  * :func:`batched_sparse_sweep_cut` — vmap of
    :func:`repro.core.sweep.sweep_cut_sparse`: the sweep gathers only
    touched vertices (sorted-support rank lookup), so B sweeps cost
    B·O(cap_v + cap_e), never B·O(n).
  * :func:`batched_cluster_sparse_fixedcap` — the fused diffusion + sparse
    sweep kernel (the sparse analogue of ``batched_cluster_fixedcap``),
    which never materializes any dense vector at all.
  * Host drivers :func:`batched_pr_nibble_sparse` /
    :func:`batched_cluster_sparse` — per-seed overflow retry on the
    capacity ladder of core/batched.py, generalized over the *frontier/value*
    capacities: a lane that overflows any of (cap_f, cap_e, cap_v) is
    repacked into a power-of-two retry batch one bucket up
    (``cap_f``/``cap_v`` clamped at n+1, ``cap_e`` unclamped until
    ``max_cap_e``) — verbatim the schedule of
    :func:`repro.core.pr_nibble_sparse.pr_nibble_sparse`, so per-seed
    results are bit-identical to the single-seed sparse driver.

Overflow/retry contract and recompile boundaries are those documented in
core/batched.py; the only new static axis is ``cap_v``.  Because the retry
loop is the shared :func:`repro.core.batched._bucketed_retry`, sparse
ladder dispatches annotate an active trace scope
(:func:`repro.serve.tracing.annotate` — bucket hops, overflow counts,
pushes) exactly like the dense driver's, with no serve import here.  The dense-vs-sparse
serving decision (:func:`pick_backend`) and the per-lane memory accounting
(:func:`sparse_lane_footprint`) live here so the engine and the benchmarks
agree on one definition.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from . import ops as _ops
from .batched import (_bucketed_retry, _prep_batch, _CapLadder,
                      LaneKernels as _LaneKernels,
                      rounds_remaining_hint as _dense_rounds_remaining_hint)
from .pr_nibble_sparse import pr_nibble_sparse_fixedcap
from .sweep import sweep_cut_sparse

__all__ = [
    "BatchedSparseDiffusionResult", "BatchedSparseClusterResult",
    "batched_pr_nibble_sparse_fixedcap", "batched_sparse_sweep_cut",
    "batched_cluster_sparse_fixedcap",
    "batched_pr_nibble_sparse", "batched_cluster_sparse",
    "sparse_rows_to_dense", "sparse_lane_footprint", "pick_backend",
    "sparse_rounds_remaining_hint", "sparse_lane_kernels",
]


def sparse_rounds_remaining_hint(iterations, frontier_count,
                                 max_iters: int = 10_000) -> np.ndarray:
    """Pending-rounds estimate for *sparse* PR-Nibble lanes.

    The sparse backend runs the same push rounds as the dense one (only the
    state container differs), so the round-count predictor is shared:
    :func:`repro.core.batched.rounds_remaining_hint` applied to the sparse
    state's ``t`` / ``frontier.count``.  Exposed here so the scheduler's
    cost model has one obvious import per backend.
    """
    return _dense_rounds_remaining_hint(iterations, frontier_count, max_iters)


# ------------------------------------------------------------ jitted kernels

@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8),
                   static_argnames=("optimized", "cap_f", "cap_e", "cap_v",
                                    "max_iters", "backend"))
def batched_pr_nibble_sparse_fixedcap(graph: CSRGraph, seeds, eps, alpha,
                                      optimized: bool, cap_f: int, cap_e: int,
                                      cap_v: int, max_iters: int = 10_000,
                                      *, backend: str = "xla"):
    """vmap of :func:`pr_nibble_sparse_fixedcap`: seeds[B], per-seed (ε, α).

    Shapes: ``seeds`` int32[B], ``eps``/``alpha`` f32[B].  Returns a
    :class:`PRNibbleSparseResult` with a leading [B] axis on every leaf:
    ``p``/``r`` are SparseVecs with ``ids`` int32[B, cap_v] (sorted,
    sentinel-``n``-padded), ``vals`` f32[B, cap_v], ``count`` int32[B];
    ``iterations``/``pushes`` int32[B]; ``overflow`` bool[B].
    """
    def one(s, e, a):
        return pr_nibble_sparse_fixedcap(graph, s, e, a, optimized,
                                         cap_f, cap_e, cap_v, max_iters,
                                         backend=backend)
    return jax.vmap(one)(seeds, eps, alpha)


@functools.partial(jax.jit, static_argnums=(4,),
                   static_argnames=("cap_e", "backend"))
def batched_sparse_sweep_cut(graph: CSRGraph, ids, vals, nnz, cap_e: int, *,
                             backend: str = "xla"):
    """vmap of :func:`sweep_cut_sparse` over B sparse diffusion vectors.

    Shapes: ``ids`` int32[B, cap_n] (sentinel ``n`` beyond each lane's
    ``nnz``), ``vals`` f32[B, cap_n], ``nnz`` int32[B]; ``cap_e`` static.
    Returns a :class:`SweepResult` with leading [B] axis — per-lane live
    memory O(cap_n + cap_e), never O(n).
    """
    def one(i, v, c):
        return sweep_cut_sparse(graph, i, v, c, cap_e, backend=backend)
    return jax.vmap(one)(ids, vals, nnz)


class _SparseClusterLanes(NamedTuple):
    """Per-lane output of the fused sparse diffusion+sweep kernel."""
    conductance: jnp.ndarray       # f32[B, cap_v] — full sweep curve
    best_conductance: jnp.ndarray  # f32[B]
    best_size: jnp.ndarray         # int32[B]
    best_volume: jnp.ndarray       # int32[B]
    order: jnp.ndarray             # int32[B, cap_v] — sweep order (cluster prefix)
    support: jnp.ndarray           # int32[B] — nnz of the diffusion
    pushes: jnp.ndarray            # int32[B]
    iterations: jnp.ndarray        # int32[B]
    overflow: jnp.ndarray          # bool[B] — diffusion OR sweep overflow


@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8),
                   static_argnames=("optimized", "cap_f", "cap_e", "cap_v",
                                    "sweep_cap_e", "backend"))
def batched_cluster_sparse_fixedcap(graph: CSRGraph, seeds, eps, alpha,
                                    optimized: bool, cap_f: int, cap_e: int,
                                    cap_v: int, sweep_cap_e: int, *,
                                    backend: str = "xla"
                                    ) -> _SparseClusterLanes:
    """Fused sparse PR-Nibble + sparse sweep per seed — no dense vector ever.

    The sweep grid is the diffusion's own ``cap_v`` (support ≤ cap_v by
    construction, so the sweep itself cannot truncate support).  Shapes as in
    :func:`batched_pr_nibble_sparse_fixedcap`; the sweep curve is
    f32[B, cap_v] (inf-padded past each lane's support).
    """
    def one(s, e, a):
        res = pr_nibble_sparse_fixedcap(graph, s, e, a, optimized,
                                        cap_f, cap_e, cap_v, backend=backend)
        sw = sweep_cut_sparse(graph, res.p.ids, res.p.vals, res.p.count,
                              sweep_cap_e, backend=backend)
        return _SparseClusterLanes(
            conductance=sw.conductance,
            best_conductance=sw.best_conductance,
            best_size=sw.best_size,
            best_volume=sw.best_volume,
            order=sw.order,
            support=sw.nnz,
            pushes=res.pushes,
            iterations=res.iterations,
            overflow=res.overflow | sw.overflow,
        )
    return jax.vmap(one)(seeds, eps, alpha)


# ------------------------------------------------- host drivers (per-seed retry)

class BatchedSparseDiffusionResult(NamedTuple):
    """Host-side batched sparse diffusion output.

    The sparse columns are ``max(cap_v over dispatched buckets)`` wide:
    lanes served by smaller buckets keep sentinel/zero padding past their
    ``count``.  ``buckets`` entries are (batch, cap_f, cap_e, cap_v).
    """
    p_ids: np.ndarray       # int32[B, capV] — sorted, sentinel-n-padded
    p_vals: np.ndarray      # f32[B, capV]
    p_count: np.ndarray     # int32[B]
    r_ids: np.ndarray       # int32[B, capV]
    r_vals: np.ndarray      # f32[B, capV]
    r_count: np.ndarray     # int32[B]
    iterations: np.ndarray  # int32[B]
    pushes: np.ndarray      # int32[B]
    overflow: np.ndarray    # bool[B] — True only if max_cap_e was exhausted
    buckets: Tuple[Tuple[int, int, int, int], ...]


class BatchedSparseClusterResult(NamedTuple):
    """Host-side fused sparse cluster output.

    Sweep curves are reported on the fixed grid of the *first* bucket's
    ``cap_v`` (same convention as ``batched_cluster``) so NCP accumulators
    see one consistent size axis.
    """
    conductance: np.ndarray       # f32[B, cap_v0]
    best_conductance: np.ndarray  # f32[B]
    best_size: np.ndarray         # int32[B]
    best_volume: np.ndarray       # int32[B]
    support: np.ndarray           # int32[B]
    pushes: np.ndarray            # int32[B]
    iterations: np.ndarray        # int32[B]
    overflow: np.ndarray          # bool[B]
    buckets: Tuple[Tuple[int, int, int, int], ...]


def _grow_sparse_out(out: dict, cap_v: int, n: int) -> None:
    """Widen the (ids, vals) output columns to ``cap_v`` when the ladder
    promotes — already-written lanes keep their data, the new tail is
    sentinel/zero padding."""
    have = out["p_ids"].shape[1]
    if have >= cap_v:
        return
    pad = cap_v - have
    for name in ("p_ids", "r_ids"):
        out[name] = np.pad(out[name], ((0, 0), (0, pad)), constant_values=n)
    for name in ("p_vals", "r_vals"):
        out[name] = np.pad(out[name], ((0, 0), (0, pad)))


def batched_pr_nibble_sparse(graph: CSRGraph, seeds, eps=1e-7, alpha=0.01,
                             optimized: bool = True, cap_f: int = 1 << 10,
                             cap_e: int = 1 << 14, cap_v: int = 1 << 12,
                             max_cap_e: int = 1 << 26,
                             max_iters: int = 10_000, backend: str = "xla"
                             ) -> BatchedSparseDiffusionResult:
    """Batched bucketed sparse driver: per-seed overflow retry on the
    (cap_f, cap_e, cap_v) ladder.  Per-seed output is bit-identical to
    looping :func:`repro.core.pr_nibble_sparse.pr_nibble_sparse` (same
    capacity schedule, same round function).

    ``seeds`` int-like[B] (scalars broadcast); ``eps``/``alpha`` broadcast to
    f32[B].  See :class:`BatchedSparseDiffusionResult` for output shapes.
    """
    graph = _ops.local_csr(graph)   # any graph-like (GraphHandle ok)
    seeds, B, eps, alpha = _prep_batch(seeds, eps, alpha)
    n = graph.n
    out = dict(p_ids=np.full((B, cap_v), n, np.int32),
               p_vals=np.zeros((B, cap_v), np.float32),
               p_count=np.zeros(B, np.int32),
               r_ids=np.full((B, cap_v), n, np.int32),
               r_vals=np.zeros((B, cap_v), np.float32),
               r_count=np.zeros(B, np.int32),
               iterations=np.zeros(B, np.int32),
               pushes=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    lad = _CapLadder(n, cap_f, cap_e, max_cap_e, cap_v=cap_v)

    def dispatch(sel):
        _grow_sparse_out(out, lad.cap_v, n)
        res = batched_pr_nibble_sparse_fixedcap(
            graph, jnp.asarray(seeds[sel]), jnp.asarray(eps[sel]),
            jnp.asarray(alpha[sel]), optimized, lad.cap_f, lad.cap_e,
            lad.cap_v, max_iters, backend=backend)
        fields = dict(p_ids=res.p.ids, p_vals=res.p.vals, p_count=res.p.count,
                      r_ids=res.r.ids, r_vals=res.r.vals, r_count=res.r.count,
                      iterations=res.iterations, pushes=res.pushes,
                      overflow=res.overflow)
        return fields, (sel.size, lad.cap_f, lad.cap_e, lad.cap_v)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out, ovf)
    return BatchedSparseDiffusionResult(overflow=ovf, buckets=buckets, **out)


def batched_cluster_sparse(graph: CSRGraph, seeds, eps=1e-6, alpha=0.01,
                           optimized: bool = True, cap_f: int = 1 << 10,
                           cap_e: int = 1 << 14, cap_v: int = 1 << 12,
                           sweep_cap_e: int = 1 << 18,
                           max_cap_e: int = 1 << 26, backend: str = "xla"
                           ) -> BatchedSparseClusterResult:
    """Batched fused sparse diffusion + sparse sweep with per-seed retry on
    *any* workspace (cap_f, cap_e, cap_v, sweep_cap_e) overflowing.

    Sweep curves are reported on the first bucket's ``cap_v`` grid (retried
    lanes' longer curves are truncated to it, matching ``batched_cluster``).
    """
    graph = _ops.local_csr(graph)   # any graph-like (GraphHandle ok)
    seeds, B, eps, alpha = _prep_batch(seeds, eps, alpha)
    n = graph.n
    out = dict(conductance=np.full((B, cap_v), np.inf, np.float32),
               best_conductance=np.full(B, np.inf, np.float32),
               best_size=np.zeros(B, np.int32),
               best_volume=np.zeros(B, np.int32),
               support=np.zeros(B, np.int32),
               pushes=np.zeros(B, np.int32),
               iterations=np.zeros(B, np.int32))
    ovf = np.zeros(B, bool)
    lad = _CapLadder(n, cap_f, cap_e, max_cap_e, cap_v=cap_v,
                     sweep_cap_e=sweep_cap_e)

    def dispatch(sel):
        res = batched_cluster_sparse_fixedcap(
            graph, jnp.asarray(seeds[sel]), jnp.asarray(eps[sel]),
            jnp.asarray(alpha[sel]), optimized, lad.cap_f, lad.cap_e,
            lad.cap_v, lad.sweep_cap_e, backend=backend)
        fields = res._asdict()
        fields.pop("order")            # not part of the host result
        return fields, (sel.size, lad.cap_f, lad.cap_e, lad.cap_v)

    buckets = _bucketed_retry(B, dispatch, lad.advance, lad.exhausted, out, ovf)
    return BatchedSparseClusterResult(overflow=ovf, buckets=buckets, **out)


# -------------------------------------------------- accounting / backend pick

def sparse_rows_to_dense(ids, vals, count, n: int) -> np.ndarray:
    """Densify host-side sparse rows: f32[B, n] from int32[B, capV] ids +
    f32[B, capV] vals + int32[B] counts (test/cross-check helper)."""
    ids = np.atleast_2d(np.asarray(ids))
    vals = np.atleast_2d(np.asarray(vals))
    count = np.atleast_1d(np.asarray(count))
    B, capv = ids.shape
    dense = np.zeros((B, n), np.float32)
    for b in range(B):
        k = int(count[b])
        dense[b, ids[b, :k]] = vals[b, :k]
    return dense


def sparse_lane_footprint(cap_f: int, cap_e: int, cap_v: int) -> dict:
    """Per-lane live-value accounting for one sparse lane (32-bit slots).

    ``state`` is what persists across rounds (p and r: ids + vals each);
    ``transient`` is the per-round peak extra (frontier ids, edge-batch
    (slot, src, dst), and the ~2(cap_v+cap_e) sort-merge scratch of
    ``sv_merge_add``).  The point of the backend: ``state`` is 4·cap_v —
    bounded by the lane's K, independent of n — while a dense lane's state
    is 2·n.
    """
    state = 4 * cap_v
    transient = cap_f + 3 * cap_e + 2 * (cap_v + cap_e)
    return dict(state=state, transient=transient, total=state + transient)


def pick_backend(n: int, cap_v: int, ratio: int = 4, *,
                 num_shards: int = 1,
                 chip_budget: int | None = None) -> str:
    """Lane-backend heuristic used by ``LocalClusterEngine``.

    Dense vs sparse: a dense lane persists 2·n values (p, r); a sparse lane
    persists 4·cap_v slots plus sort-merge scratch and pays an O(log cap_v)
    factor on every lookup.  Choose sparse only when the dense state is at
    least ``ratio``× the sparse state: n ≥ 2·ratio·cap_v.

    Fits-on-chip: when the graph is sharded (``num_shards > 1``) and a
    ``chip_budget`` (bytes) is given, a query whose dense per-lane state
    2·4·n would blow the budget is routed to the distributed lanes
    (``"dist"``) — the state then lives sharded, O(n/D) per chip.  With no
    budget configured the local heuristic applies unchanged (sharding alone
    never forces the slower multi-chip rounds onto a graph that fits).
    Requests can always pin a backend explicitly (``ClusterRequest.backend``).
    """
    if num_shards > 1 and chip_budget is not None and 8 * n > chip_budget:
        return "dist"
    return "sparse" if n >= 2 * ratio * cap_v else "dense"


# ------------------------------------------- executable-shaped lane kernels

@functools.lru_cache(maxsize=None)
def sparse_lane_kernels(n: int, statics: tuple, cap_f: int, cap_v: int,
                        cap_e: int, sweep_cap_e: int, rounds: int,
                        backend: str) -> _LaneKernels:
    """Sparse-lane kernel bundle for the serving engine — the SparseVec
    analogue of :func:`repro.core.batched.dense_lane_kernels` (same
    ``LaneKernels`` signature set, same donation/AOT contract).  The sweep
    kernel gathers only the finished lane's ``(ids, vals, count)`` support
    — O(cap_v), never O(n) — before running the sparse Theorem-1 sweep, so
    harvests copy support, not pool state.  ``statics = (optimized, β)``
    with β fixed at 1.0 (sparse lanes serve plain PR-Nibble only)."""
    from .pr_nibble_sparse import (pr_nibble_sparse_init,
                                   pr_nibble_sparse_round,
                                   pr_nibble_sparse_alive)
    optimized, _beta = statics
    seed_init = lambda s: pr_nibble_sparse_init(s, n, cap_f, cap_v)

    @jax.jit
    def init(seeds):
        return jax.vmap(seed_init)(seeds)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def inject(state, lane, seed):
        return jax.tree.map(lambda buf, v: buf.at[lane].set(v),
                            state, seed_init(seed))

    @functools.partial(jax.jit, donate_argnums=(1,))
    def step(graph, state, eps, alpha, active):
        def one(s, e, a, act):
            def cond(c):
                s2, k = c
                return act & (k < rounds) & pr_nibble_sparse_alive(s2, 10_000)

            def body(c):
                s2, k = c
                return (pr_nibble_sparse_round(graph, s2, e, a, optimized,
                                               cap_e, backend),
                        k + 1)

            s2, _ = jax.lax.while_loop(cond, body,
                                       (s, jnp.asarray(0, jnp.int32)))
            return s2
        return jax.vmap(one)(state, eps, alpha, active)

    @jax.jit
    def status(state):
        fc = state.frontier.count.astype(jnp.int32)
        fin = (fc == 0) | state.overflow | (state.t >= 10_000)
        return jnp.stack([fin.astype(jnp.int32),
                          state.overflow.astype(jnp.int32), fc,
                          state.t.astype(jnp.int32),
                          state.pushes.astype(jnp.int32),
                          jnp.zeros_like(fc)])

    @jax.jit
    def sweep(graph, state, lane):
        sw = sweep_cut_sparse(graph, state.p.ids[lane], state.p.vals[lane],
                              state.p.count[lane], sweep_cap_e,
                              backend=backend)
        meta = jnp.stack([sw.best_size, sw.best_volume, sw.nnz,
                          sw.overflow.astype(jnp.int32)])
        return sw.order, meta, sw.best_conductance

    return _LaneKernels(init, inject, step, status, sweep)
