"""Figures 8/9 reproduction: sweep cut runtime vs cluster volume.

Paper claim (C4): parallel sweep time scales ~linearly with the input
volume (the super-linear sort is a small fraction).  We grow the cluster by
loosening Nibble's ε (exactly the paper's methodology) and report µs vs
vol(S_N), plus the fitted scaling exponent.
"""
import numpy as np

from repro.core import nibble, sweep_cut_dense
from .common import get_graph, emit, timeit


def run(graph_name: str = "randLocal-50k"):
    g = get_graph(graph_name)
    seed = int(np.argmax(np.asarray(g.deg)))
    vols, times = [], []
    for eps in (1e-5, 1e-6, 1e-7, 1e-8, 1e-9):
        res = nibble(g, seed, eps, 20)
        p = np.asarray(res.p)
        nnz = int((p > 0).sum())
        vol = int(np.asarray(g.deg)[p > 0].sum())
        if nnz < 4:
            continue
        us, sw = timeit(sweep_cut_dense, g, res.p, 1 << 13, 1 << 19)
        emit(f"fig9/{graph_name}/eps={eps:g}", us,
             f"nnz={nnz};vol={vol};cond={float(sw.best_conductance):.4f}")
        vols.append(vol)
        times.append(us)
    if len(vols) >= 3:
        # scaling exponent from log-log fit (≈1 = linear)
        k = np.polyfit(np.log(vols), np.log(times), 1)[0]
        emit(f"fig9/{graph_name}/scaling_exponent", 0.0, f"k={k:.2f}")


if __name__ == "__main__":
    run()
