"""Model-layer correctness: flash/local attention vs naive oracle, SSD vs
step recurrence, RG-LRU scan vs loop, prefill↔decode consistency."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.attention import (flash_causal, local_causal, full_bidir,
                                    expand_kv)
from repro.models.ssm import mamba2_init, mamba2_apply, mamba2_decode_step
from repro.models.rglru import rglru_init, rglru_apply, rglru_decode_step
from repro.models import build_model
from repro.configs import smoke_config


def naive_causal(q, k, v, window=None):
    b, s, h, dh = q.shape
    sc = jnp.einsum("bqhd,bshd->bhqs", q * dh ** -0.5, k)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = qpos >= kpos
    if window is not None:
        mask = mask & (qpos - kpos < window)
    sc = jnp.where(mask[None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v)


@pytest.mark.parametrize("s,qc,kc", [(128, 16, 32), (256, 64, 64),
                                     (96, 32, 96)])
def test_flash_causal_matches_naive(s, qc, kc):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.standard_normal((2, s, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, s, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, s, 4, 16)), jnp.float32)
    out = flash_causal(q, k, v, qc, kc)
    exp = naive_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


@pytest.mark.parametrize("s,w,qc", [(256, 32, 32), (512, 64, 64)])
def test_local_causal_matches_naive_window(s, w, qc):
    rng = np.random.default_rng(s + w)
    q = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, s, 2, 8)), jnp.float32)
    out = local_causal(q, k, v, window=w, q_chunk=qc)
    exp = naive_causal(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_expand_kv_gqa_grouping():
    kv = jnp.arange(2 * 3 * 2 * 4, dtype=jnp.float32).reshape(2, 3, 2, 4)
    full = expand_kv(kv, 6)
    assert full.shape == (2, 3, 6, 4)
    # heads 0..2 repeat kv head 0, heads 3..5 repeat kv head 1
    np.testing.assert_allclose(np.asarray(full[:, :, 0]), np.asarray(kv[:, :, 0]))
    np.testing.assert_allclose(np.asarray(full[:, :, 2]), np.asarray(kv[:, :, 0]))
    np.testing.assert_allclose(np.asarray(full[:, :, 3]), np.asarray(kv[:, :, 1]))


def _ssm_cfg(chunk):
    return ModelConfig(arch_id="t", n_layers=1, d_model=32, n_heads=4,
                       n_kv_heads=4, d_ff=0, vocab=64,
                       layer_pattern=("mamba2",), ff_kind="none",
                       ssm_state=8, ssm_head_dim=8, ssm_expand=2,
                       ssm_chunk=chunk, param_dtype="float32",
                       compute_dtype="float32")


def test_mamba2_chunked_equals_stepwise():
    """Chunked SSD == token-by-token recurrence (the state-space duality)."""
    cfg = _ssm_cfg(chunk=8)
    params = mamba2_init(jax.random.PRNGKey(0), cfg, "float32")
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.standard_normal((2, 32, 32)) * 0.3, jnp.float32)
    y_chunk = mamba2_apply(params, u, cfg)

    state = jnp.zeros((2, 8, 8, 8), jnp.float32)  # [B,H,P,N]
    ys = []
    for t in range(32):
        y1, state = mamba2_decode_step(params, u[:, t: t + 1], state, cfg)
        ys.append(y1)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               atol=2e-4)


def test_mamba2_chunk_size_invariance():
    cfg8, cfg16 = _ssm_cfg(8), _ssm_cfg(16)
    params = mamba2_init(jax.random.PRNGKey(1), cfg8, "float32")
    u = jnp.asarray(np.random.default_rng(1).standard_normal((1, 32, 32)) * 0.3,
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(mamba2_apply(params, u, cfg8)),
                               np.asarray(mamba2_apply(params, u, cfg16)),
                               atol=2e-4)


def test_rglru_scan_equals_stepwise():
    cfg = ModelConfig(arch_id="t", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=1, d_ff=32, vocab=64,
                      layer_pattern=("rglru",), param_dtype="float32",
                      compute_dtype="float32")
    params = rglru_init(jax.random.PRNGKey(0), cfg, "float32")
    u = jnp.asarray(np.random.default_rng(2).standard_normal((2, 16, 16)) * 0.5,
                    jnp.float32)
    y_scan = rglru_apply(params, u, cfg)
    state = {"h": jnp.zeros((2, 16), jnp.float32),
             "conv": jnp.zeros((2, cfg.rglru_conv_width - 1, 16), jnp.float32)}
    ys = []
    for t in range(16):
        y1, state = rglru_decode_step(params, u[:, t: t + 1], state, cfg)
        ys.append(y1)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               atol=1e-4)


@pytest.mark.parametrize("arch", ["yi-6b", "mamba2-2.7b", "recurrentgemma-2b"])
def test_prefill_decode_consistency(arch):
    """decode(prefill(x[:s]), x[s]) logits == teacher-forced forward logits."""
    cfg = smoke_config(arch)
    m = build_model(cfg, remat=False)
    params = m.init_fn(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)), jnp.int32)
    # prefill on the first 16, then decode token 16
    cache, logits16 = m.prefill_fn(params, {"tokens": tok[:, :16]})
    dec_logits, _ = m.decode_fn(params, tok[:, 16:17], cache)
    # oracle: full forward over 17 tokens; logits at position 16
    cache2, logits17 = m.prefill_fn(params, {"tokens": tok})
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(logits17),
                               atol=2e-2, rtol=2e-2)
