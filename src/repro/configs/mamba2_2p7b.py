"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060; unverified].
64L d_model=2560, attention-free, d_ff=0, vocab=50280, ssm_state=128."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    n_layers=64, d_model=2560, n_heads=80, n_kv_heads=80,
    d_ff=0, vocab=50280,
    layer_pattern=("mamba2",), ff_kind="none",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    source="arXiv:2405.21060 (unverified)",
)
