"""Sequential reference implementations (the paper's baselines).

Faithful host-side numpy ports of the *sequential* algorithms exactly as the
paper describes them (queue-based PR-Nibble §4.3, queue-of-(v,j) HK-PR §4.4,
walk-at-a-time rand-HK-PR §4.5, incremental sweep §4.1).  They serve as

  1. the "sequential" column of Table 1 / Table 3 reproductions, and
  2. correctness oracles for the parallel JAX engines.

Dict-backed sparse sets stand in for STL ``unordered_map``.
"""
from __future__ import annotations

import collections
import math
from typing import Dict, Tuple

import numpy as np

from repro.graphs.csr import CSRGraph

__all__ = ["seq_sweep_cut", "seq_nibble", "seq_pr_nibble", "seq_hk_pr",
           "seq_rand_hk_pr", "seq_evolving_sets", "conductance_of_set"]


def _adj(graph: CSRGraph):
    g = graph.to_numpy()
    return g.indptr, g.indices, g.deg, g.n, g.m


def conductance_of_set(graph: CSRGraph, S) -> float:
    indptr, indices, deg, n, m = _adj(graph)
    Sset = set(int(v) for v in S)
    vol = sum(int(deg[v]) for v in Sset)
    cut = 0
    for v in Sset:
        for w in indices[indptr[v]: indptr[v + 1]]:
            if int(w) not in Sset:
                cut += 1
    denom = min(vol, 2 * m - vol)
    return cut / denom if denom > 0 else math.inf


def seq_sweep_cut(graph: CSRGraph, p: Dict[int, float]):
    """§4.1 sequential sweep: sort by p/d desc, incremental ∂(S), vol(S)."""
    indptr, indices, deg, n, m = _adj(graph)
    items = [(v, val) for v, val in p.items() if val > 0 and deg[v] > 0]
    items.sort(key=lambda kv: (-kv[1] / deg[kv[0]], kv[0]))
    S = set()
    vol = 0
    cut = 0
    best = (math.inf, 0, 0)  # (conductance, prefix length, volume)
    conds = []
    for i, (v, _) in enumerate(items):
        for w in indices[indptr[v]: indptr[v + 1]]:
            cut += -1 if int(w) in S else 1
        S.add(v)
        vol += int(deg[v])
        denom = min(vol, 2 * m - vol)
        cond = cut / denom if denom > 0 else math.inf
        conds.append(cond)
        if cond < best[0]:
            best = (cond, i + 1, vol)
    order = [v for v, _ in items]
    return dict(best_conductance=best[0], best_size=best[1],
                best_volume=best[2], order=order, conductance=conds,
                cluster=order[: best[1]])


def seq_nibble(graph: CSRGraph, x: int, eps: float, T: int):
    """§4.2: truncated lazy random walk.  (The parallel algorithm applies the
    same updates, so this is also the parallel oracle.)"""
    indptr, indices, deg, n, m = _adj(graph)
    p = {int(x): 1.0}
    iters = 0
    pushes = 0
    for _ in range(T):
        frontier = [v for v, pv in p.items() if pv >= deg[v] * eps]
        if not frontier:
            break
        p_new: Dict[int, float] = collections.defaultdict(float)
        for v in frontier:
            pv = p[v]
            p_new[v] += pv / 2
            share = pv / (2 * deg[v])
            for w in indices[indptr[v]: indptr[v + 1]]:
                p_new[int(w)] += share
            pushes += 1
        iters += 1
        nxt_frontier = [v for v, pv in p_new.items() if pv >= deg[v] * eps]
        if not nxt_frontier:
            break  # return p_{i-1}? paper: break leaving p as previous round
        p = dict(p_new)
    return dict(p=p, iterations=iters, pushes=pushes)


def seq_pr_nibble(graph: CSRGraph, x: int, eps: float, alpha: float,
                  optimized: bool = True, max_pushes: int = 10**9):
    """§4.3: queue-based sequential PR-Nibble, both update rules."""
    indptr, indices, deg, n, m = _adj(graph)
    p: Dict[int, float] = collections.defaultdict(float)
    r: Dict[int, float] = collections.defaultdict(float)
    r[int(x)] = 1.0
    q = collections.deque([int(x)])
    inq = {int(x)}
    pushes = 0
    while q and pushes < max_pushes:
        v = q.popleft()
        inq.discard(v)
        # "We repeatedly push from v until it is below the threshold."  With
        # the optimized rule r[v] becomes 0 after one push, so the loop runs
        # once; with the original rule it halves until below threshold.
        while deg[v] > 0 and r[v] >= deg[v] * eps and pushes < max_pushes:
            rv = r[v]
            if optimized:
                p[v] += (2 * alpha / (1 + alpha)) * rv
                share = ((1 - alpha) / (1 + alpha)) * rv / deg[v]
                r[v] = 0.0
            else:
                p[v] += alpha * rv
                share = (1 - alpha) * rv / (2 * deg[v])
                r[v] = (1 - alpha) * rv / 2
            for w in indices[indptr[v]: indptr[v + 1]]:
                w = int(w)
                r[w] += share
                if deg[w] > 0 and r[w] >= deg[w] * eps and w not in inq:
                    q.append(w)
                    inq.add(w)
            pushes += 1
    return dict(p=dict(p), r=dict(r), pushes=pushes)


def _psis(N: int, t: float) -> np.ndarray:
    """ψ_k = Σ_{m=0}^{N-k} k! t^m/(m+k)!  via ψ_N = 1, ψ_k = 1 + t·ψ_{k+1}/(k+1)."""
    psi = np.ones(N + 1, dtype=np.float64)
    for k in range(N - 1, -1, -1):
        psi[k] = 1.0 + t * psi[k + 1] / (k + 1)
    return psi


def seq_hk_pr(graph: CSRGraph, x: int, N: int, eps: float, t: float,
              truncate: bool = True):
    """§4.4: Kloster–Gleich deterministic heat-kernel push (queue of (v,j)).

    Threshold follows Figure 5 / Kloster–Gleich: an entry (w, j+1) enters the
    queue when r[(w,j+1)] crosses eᵗ·ε·d(w) / (2N·ψ_{j+1}(t)).  With
    ``truncate=False`` the full degree-N Taylor recurrence is applied (the
    ε→0 oracle).
    """
    indptr, indices, deg, n, m = _adj(graph)
    psi = _psis(N, t)
    p: Dict[int, float] = collections.defaultdict(float)
    r: Dict[Tuple[int, int], float] = collections.defaultdict(float)
    r[(int(x), 0)] = 1.0
    q = collections.deque([(int(x), 0)])
    pushes = 0
    scale = math.exp(t)
    while q:
        v, j = q.popleft()
        rv = r.pop((v, j), 0.0)
        if rv == 0.0 or deg[v] == 0:
            continue
        p[v] += rv
        pushes += 1
        if j + 1 == N:
            share = rv / deg[v]
            for w in indices[indptr[v]: indptr[v + 1]]:
                p[int(w)] += share
            continue
        M = t * rv / ((j + 1) * deg[v])
        for w in indices[indptr[v]: indptr[v + 1]]:
            w = int(w)
            thresh = scale * eps * deg[w] / (2 * N * psi[j + 1])
            old = r[(w, j + 1)]
            if truncate:
                if old < thresh and old + M >= thresh:
                    q.append((w, j + 1))
            else:
                if old == 0.0:
                    q.append((w, j + 1))
            r[(w, j + 1)] = old + M
    return dict(p=dict(p), pushes=pushes)


def seq_rand_hk_pr(graph: CSRGraph, x: int, N: int, K: int, t: float,
                   seed: int = 0):
    """§4.5: N random walks, length ~ Poisson(t) truncated at K; p[v] counts
    walks ending at v; returned vector is p/N."""
    indptr, indices, deg, n, m = _adj(graph)
    rng = np.random.default_rng(seed)
    # truncated Poisson(t) CDF table over 0..K
    pmf = np.array([math.exp(-t) * t ** k / math.factorial(k) for k in range(K + 1)])
    pmf[-1] += max(0.0, 1.0 - pmf.sum())
    cdf = np.cumsum(pmf / pmf.sum())
    p: Dict[int, float] = collections.defaultdict(float)
    for _ in range(N):
        k = int(np.searchsorted(cdf, rng.random()))
        v = int(x)
        for _step in range(k):
            if deg[v] == 0:
                break
            v = int(indices[indptr[v] + rng.integers(deg[v])])
        p[v] += 1.0
    return dict(p={v: c / N for v, c in p.items()})


def seq_evolving_sets(graph: CSRGraph, x: int, T: int, B: int, phi: float,
                      seed: int = 0):
    """§4.6: Andersen–Peres evolving sets (sequential, sparse sets)."""
    indptr, indices, deg, n, m = _adj(graph)
    rng = np.random.default_rng(seed)
    S = {int(x)}
    x_walk = int(x)
    work = 0
    history = []
    for t_iter in range(T):
        # 1. lazy walk step
        if rng.random() >= 0.5 and deg[x_walk] > 0:
            x_walk = int(indices[indptr[x_walk] + rng.integers(deg[x_walk])])
        # e(v, S) for v in S ∪ ∂S
        e_vS: Dict[int, int] = collections.defaultdict(int)
        for u in S:
            for w in indices[indptr[u]: indptr[u + 1]]:
                e_vS[int(w)] += 1
            work += int(deg[u])
        cands = set(e_vS) | S

        def p_vS(v):
            base = e_vS.get(v, 0) / (2 * deg[v]) if deg[v] > 0 else 0.0
            return base + (0.5 if v in S else 0.0)

        # 2–3. threshold update
        z = rng.random() * p_vS(x_walk)
        S = {v for v in cands if p_vS(v) >= z and deg[v] > 0}
        if not S:
            S = {int(x)}
        cond = conductance_of_set(graph, S)
        history.append((len(S), cond))
        if cond < phi or work > B:
            break
    return dict(S=sorted(S), conductance=conductance_of_set(graph, S),
                work=work, history=history)
