from .engine import ServeConfig, generate, batched_serve
from .cluster_engine import (ClusterRequest, ClusterResult,
                             LocalClusterEngine, UnknownTicket)
from .scheduler import AsyncClusterEngine, ClusterFuture, QueueFull
from .telemetry import MetricsRegistry, pool_label
from .tracing import RequestTrace, Span, Tracer, annotate
from .result_cache import ResultCache, result_key

__all__ = ["ServeConfig", "generate", "batched_serve",
           "ClusterRequest", "ClusterResult", "LocalClusterEngine",
           "UnknownTicket", "AsyncClusterEngine", "ClusterFuture",
           "QueueFull", "MetricsRegistry", "pool_label",
           "RequestTrace", "Span", "Tracer", "annotate",
           "ResultCache", "result_key"]
