"""Batched multi-seed engine (core/batched.py) and the clustering service
(serve/cluster_engine.py) vs the single-seed drivers.

The contract under test: batching is a throughput optimization, never a
semantics change — per-seed outputs are *identical* to looping the
single-seed drivers, including through the per-seed overflow retry ladder,
and the whole batch compiles at most O(log) distinct bucket shapes.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (pr_nibble, pr_nibble_sparse, hk_pr, sweep_cut_dense,
                        batched_pr_nibble, batched_hk_pr, batched_cluster,
                        batched_sweep_cut)
from repro.core.batched import rounds_remaining_hint, hk_rounds_remaining
from repro.serve import ClusterRequest, LocalClusterEngine, UnknownTicket

# Right-sized workspaces for the small test graphs: one compile per kernel
# (rand_local-2000 has vol <= 2m = 19082 < 2^15; frontiers fit in 2^11).
CAPS = dict(cap_f=1 << 11, cap_e=1 << 15)
SWEEP = dict(cap_n=1 << 10, sweep_cap_e=1 << 15)
ENGINE_CAPS = dict(cap_f=1 << 11, cap_e=1 << 15, cap_n=1 << 10,
                   sweep_cap_e=1 << 15)


def _mixed_params(local_graph, B, seed=0):
    rng = np.random.default_rng(seed)
    deg = np.asarray(local_graph.deg)
    seeds = rng.choice(np.flatnonzero(deg > 0), size=B).astype(np.int32)
    eps = rng.choice([1e-5, 1e-6], size=B).astype(np.float32)
    alpha = rng.choice([0.05, 0.01], size=B).astype(np.float32)
    return seeds, eps, alpha


# ------------------------------------------------- (a) batched == single-seed

def test_batched_pr_nibble_matches_single_seed(local_graph):
    """Acceptance: ≥32 seeds on rand_local, per-seed p/pushes identical to
    looping pr_nibble, O(log) distinct compiled bucket shapes."""
    B = 32
    seeds, eps, alpha = _mixed_params(local_graph, B)
    out = batched_pr_nibble(local_graph, seeds, eps, alpha, **CAPS)
    for i in range(B):
        ref = pr_nibble(local_graph, int(seeds[i]), float(eps[i]),
                        float(alpha[i]), **CAPS)
        np.testing.assert_array_equal(out.p[i], np.asarray(ref.p))
        np.testing.assert_array_equal(out.r[i], np.asarray(ref.r))
        assert int(out.pushes[i]) == int(ref.pushes)
        assert int(out.iterations[i]) == int(ref.iterations)
    assert not out.overflow.any()
    # one capacity bucket sufficed -> exactly one compiled shape
    assert len(set(out.buckets)) == 1


def test_batched_pr_nibble_matches_sparse_backend(local_graph):
    """Cross-check against the SparseVec backend (paper-faithful memory)."""
    B = 4
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=1)
    out = batched_pr_nibble(local_graph, seeds, eps, alpha, **CAPS)
    for i in range(B):
        s = pr_nibble_sparse(local_graph, int(seeds[i]), float(eps[i]),
                             float(alpha[i]))
        ids = np.asarray(s.p.ids)[: int(s.p.count)]
        vals = np.asarray(s.p.vals)[: int(s.p.count)]
        p_sparse = np.zeros(local_graph.n, np.float32)
        p_sparse[ids] = vals
        np.testing.assert_allclose(p_sparse, out.p[i], atol=1e-6)
        assert int(s.pushes) == int(out.pushes[i])


def test_batched_hk_pr_matches_single_seed(local_graph):
    B = 4
    seeds, _, _ = _mixed_params(local_graph, B, seed=2)
    eps = np.full(B, 1e-5, np.float32)
    out = batched_hk_pr(local_graph, seeds, N=10, eps=eps, t=5.0, **CAPS)
    for i in range(B):
        ref = hk_pr(local_graph, int(seeds[i]), N=10, eps=1e-5, t=5.0, **CAPS)
        np.testing.assert_array_equal(out.p[i], np.asarray(ref.p))
        assert int(out.pushes[i]) == int(ref.pushes)


def test_batched_sweep_matches_single(local_graph):
    B = 4
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=3)
    diff = batched_pr_nibble(local_graph, seeds, eps, alpha, **CAPS)
    sw = batched_sweep_cut(local_graph, jnp.asarray(diff.p), 1 << 10, 1 << 15)
    for i in range(B):
        ref = sweep_cut_dense(local_graph, jnp.asarray(diff.p[i]),
                              1 << 10, 1 << 15)
        assert float(sw.best_conductance[i]) == float(ref.best_conductance)
        assert int(sw.best_size[i]) == int(ref.best_size)


# ------------------------------------------------- (b) per-seed overflow retry

def test_batched_overflow_retry_converges(local_graph):
    """Deliberately tiny caps: every seed overflows the first buckets, the
    retry ladder climbs, and results still equal the single-seed driver
    (which retries on the same doubling schedule)."""
    B = 8
    seeds, eps, alpha = _mixed_params(local_graph, B, seed=4)
    cap_f0, cap_e0 = 1 << 6, 1 << 8
    out = batched_pr_nibble(local_graph, seeds, eps, alpha,
                            cap_f=cap_f0, cap_e=cap_e0)
    assert not out.overflow.any()
    assert len(out.buckets) > 1          # retries actually happened
    # the ladder is the power-of-two schedule: O(log(max_vol/cap_e0)) buckets
    cap_es = [b[2] for b in out.buckets]
    assert cap_es == sorted(set(cap_es)), "each bucket dispatched once"
    assert len(out.buckets) <= 26        # log2(max_cap_e) bound
    for i in range(B):
        ref = pr_nibble(local_graph, int(seeds[i]), float(eps[i]),
                        float(alpha[i]), cap_f=cap_f0, cap_e=cap_e0)
        np.testing.assert_array_equal(out.p[i], np.asarray(ref.p))
        assert int(out.pushes[i]) == int(ref.pushes)


def test_batched_cluster_matches_per_seed_sweep(sbm_graph):
    B = 8
    rng = np.random.default_rng(5)
    seeds = rng.integers(0, sbm_graph.n, size=B).astype(np.int32)
    out = batched_cluster(sbm_graph, seeds, 1e-6, 0.05, **CAPS, **SWEEP)
    for i in range(B):
        ref = pr_nibble(sbm_graph, int(seeds[i]), 1e-6, 0.05, **CAPS)
        sw = sweep_cut_dense(sbm_graph, ref.p, min(1 << 10, sbm_graph.n),
                             1 << 14)
        assert float(out.best_conductance[i]) == pytest.approx(
            float(sw.best_conductance), rel=1e-6)
        assert int(out.best_size[i]) == int(sw.best_size)
        assert int(out.pushes[i]) == int(ref.pushes)


# ------------------------------------------------- (c) LocalClusterEngine

def _engine_reference(g, q):
    if q.method == "pr_nibble":
        res = pr_nibble(g, q.seed, q.eps, q.alpha, q.optimized)
    else:
        res = hk_pr(g, q.seed, N=q.N, eps=q.eps, t=q.t)
    return res


def test_engine_drains_mixed_queue_with_slot_refill(sbm_graph):
    """More requests than lanes, heterogeneous (α, ε) and mixed methods:
    every request completes, in order, matching the single-seed drivers."""
    rng = np.random.default_rng(6)
    reqs = []
    for i in range(10):
        seed = int(rng.integers(0, sbm_graph.n))
        if i % 3 == 2:
            reqs.append(ClusterRequest(seed=seed, method="hk_pr",
                                       eps=1e-5, N=10, t=5.0))
        else:
            reqs.append(ClusterRequest(
                seed=seed, alpha=float(rng.choice([0.05, 0.01])),
                eps=float(rng.choice([1e-5, 1e-6]))))
    eng = LocalClusterEngine(sbm_graph, batch_slots=4, **ENGINE_CAPS)
    results = eng.run(reqs)
    assert len(results) == len(reqs)
    for r, q in zip(results, reqs):
        assert r.request is q            # order preserved
        ref = _engine_reference(sbm_graph, q)
        sw = sweep_cut_dense(sbm_graph, ref.p, min(1 << 10, sbm_graph.n),
                             1 << 14)
        assert r.pushes == int(ref.pushes)
        assert r.conductance == pytest.approx(float(sw.best_conductance),
                                              rel=1e-6)
        assert r.size == int(sw.best_size)
        assert not r.overflow
    # slot refill: 10 requests through 4 lanes of 2 method pools
    assert eng.stats["injections"] == 10
    assert eng.stats["completed"] == 10
    assert eng.stats["steps"] > 0
    assert eng.stats["pools_created"] == 2


def test_engine_overflow_promotion(sbm_graph):
    """Tiny capacity buckets: requests climb the ladder and still finish with
    push counts equal to the bucketed single-seed driver."""
    reqs = [ClusterRequest(seed=s, alpha=0.05, eps=1e-6) for s in (5, 105, 205)]
    eng = LocalClusterEngine(sbm_graph, batch_slots=2,
                             cap_f=1 << 8, cap_e=1 << 10,
                             cap_n=1 << 8, sweep_cap_e=1 << 10)
    results = eng.run(reqs)
    assert eng.stats["promotions"] > 0
    for r, q in zip(results, reqs):
        ref = pr_nibble(sbm_graph, q.seed, q.eps, q.alpha,
                        cap_f=1 << 8, cap_e=1 << 10)
        assert r.pushes == int(ref.pushes)
        assert not r.overflow
    # bucketed recompilation stays logarithmic
    shapes = eng.stats["bucket_shapes"]
    assert 0 < len(shapes) <= 26


def test_engine_incremental_submit_poll(sbm_graph):
    """submit/poll/result: the non-blocking interface drains too."""
    eng = LocalClusterEngine(sbm_graph, batch_slots=4, **ENGINE_CAPS)
    t1 = eng.submit(ClusterRequest(seed=5, alpha=0.05, eps=1e-5))
    t2 = eng.submit(ClusterRequest(seed=305, alpha=0.05, eps=1e-5))
    while eng.poll():
        pass
    r1, r2 = eng.result(t1), eng.result(t2)
    assert r1.request.seed == 5 and r2.request.seed == 305
    assert r1.size > 0 and r2.size > 0


def test_engine_rejects_unknown_method(sbm_graph):
    eng = LocalClusterEngine(sbm_graph)
    with pytest.raises(ValueError, match="unknown method"):
        eng.submit(ClusterRequest(seed=1, method="nibble"))


def test_engine_unknown_ticket_and_peek(sbm_graph):
    """result()/peek() diagnose never-issued, pending, and consumed tickets
    with UnknownTicket (a KeyError subclass), and peek never consumes."""
    eng = LocalClusterEngine(sbm_graph, batch_slots=2, **ENGINE_CAPS)
    with pytest.raises(UnknownTicket, match="never issued"):
        eng.result(0)
    with pytest.raises(KeyError):          # subclass contract
        eng.result(0)
    t = eng.submit(ClusterRequest(seed=5, alpha=0.05, eps=1e-5))
    assert eng.peek(t) is None             # pending → None, not an error
    with pytest.raises(UnknownTicket, match="still in flight"):
        eng.result(t)
    eng.drain()
    first = eng.peek(t)
    assert first is not None and eng.peek(t) is first   # non-consuming
    assert eng.result(t) is first
    with pytest.raises(UnknownTicket, match="already consumed"):
        eng.result(t)
    with pytest.raises(UnknownTicket, match="already consumed"):
        eng.peek(t)
    with pytest.raises(UnknownTicket, match="never issued"):
        eng.peek(t + 99)


def test_engine_poll_fairness_two_pools(sbm_graph):
    """A continuously-refilled hot pool must not starve a cold pool's
    harvest: the cold request completes within the polls its solo run needs
    even while the hot pool receives a new request every poll."""
    cold_req = ClusterRequest(seed=7, method="hk_pr", eps=1e-5, N=8, t=5.0)
    solo = LocalClusterEngine(sbm_graph, batch_slots=2, rounds_per_step=2,
                              **ENGINE_CAPS)
    ct = solo.submit(cold_req)
    solo_polls = 0
    while solo.peek(ct) is None:
        solo.poll()
        solo_polls += 1

    eng = LocalClusterEngine(sbm_graph, batch_slots=2, rounds_per_step=2,
                             **ENGINE_CAPS)
    cold = eng.submit(cold_req)
    rng = np.random.default_rng(7)
    hot = []
    polls = 0
    while eng.peek(cold) is None:
        # hot pool refilled before every poll — and submit marks it MRU
        hot.append(eng.submit(ClusterRequest(
            seed=int(rng.integers(0, sbm_graph.n)), alpha=0.05, eps=1e-5)))
        eng.poll()
        polls += 1
        assert polls <= solo_polls + 1, \
            "hot-pool refills delayed the cold pool's harvest"
    # LRU fairness invariant: both pools progressed, so the pool order now
    # ends with the most recently progressed; the cold pool (idle once
    # harvested) must not have been pushed behind unvisited work
    eng.drain()
    for t in hot:
        assert eng.result(t).size >= 0
    assert eng.result(cold).pushes > 0


def test_engine_eviction_promotion_mixed_stream(sbm_graph):
    """LRU pool eviction + bucket promotion under a mixed dense/sparse,
    mixed ops_backend request stream: counters move and every ticket still
    resolves."""
    eng = LocalClusterEngine(sbm_graph, batch_slots=2,
                             cap_f=1 << 6, cap_e=1 << 8,
                             cap_n=1 << 6, sweep_cap_e=1 << 8,
                             lru_pools=2)
    rng = np.random.default_rng(8)
    reqs = []
    for i in range(12):
        seed = int(rng.integers(0, sbm_graph.n))
        if i % 4 == 0:
            reqs.append(ClusterRequest(seed=seed, alpha=0.05, eps=1e-5,
                                       backend="dense", ops_backend="xla"))
        elif i % 4 == 1:
            reqs.append(ClusterRequest(seed=seed, alpha=0.05, eps=1e-5,
                                       backend="sparse", ops_backend="xla"))
        elif i % 4 == 2:
            reqs.append(ClusterRequest(seed=seed, alpha=0.05, eps=1e-5,
                                       backend="dense", ops_backend="pallas"))
        else:
            reqs.append(ClusterRequest(seed=seed, method="hk_pr", eps=1e-5,
                                       N=8, t=5.0))
    tickets = [eng.submit(r) for r in reqs]
    eng.drain()
    results = [eng.result(t) for t in tickets]   # every ticket resolves
    s = eng.stats
    assert s["completed"] == len(reqs)
    assert s["promotions"] > 0, "tiny caps must force bucket promotion"
    assert s["pools_evicted"] > 0, "4 pool families > lru_pools=2 must evict"
    assert len(eng.pools) <= 2
    shapes = s["bucket_shapes"]
    assert {sh[1] for sh in shapes} == {"dense", "sparse"}
    assert {sh[2] for sh in shapes} == {"xla", "pallas"}
    for r, q in zip(results, reqs):
        assert r.request is q
        assert not r.overflow
        assert r.size > 0 and np.isfinite(r.conductance)
        assert r.backend == (q.backend or "dense")
        assert r.ops_backend == (q.ops_backend or eng.ops_backend)


def test_rounds_remaining_hints():
    """The scheduler cost-model hints: done lanes report 0; live PR-Nibble
    lanes report the clamped survival estimate; HK lanes are exact."""
    np.testing.assert_array_equal(
        rounds_remaining_hint([0, 3, 9_999], [1, 1, 1], max_iters=10_000),
        [1, 3, 1])
    np.testing.assert_array_equal(
        rounds_remaining_hint([5, 5], [0, 4]), [0, 5])
    np.testing.assert_array_equal(
        hk_rounds_remaining([0, 3, 5], [False, False, True], [1, 1, 1], N=5),
        [5, 2, 0])
    np.testing.assert_array_equal(
        hk_rounds_remaining([2], [False], [0], N=5), [0])
