"""Parallel randomized heat-kernel PageRank (paper §4.5, Chung–Simpson).

N random walks from the seed; walk length ~ Poisson(t) truncated at K;
p[v] = (#walks ending at v)/N.  The paper's parallelization insight is the
*histogram*: naive concurrent fetch-adds on the destination counts contend
badly, so instead the N destinations are written to an array, sorted, and
counted with prefix-sums + filter.  That is precisely the TPU-native
formulation — here the walks are a vmapped `lax.scan` and the histogram is
``sort → adjacent-diff mask → cumsum compaction`` (identical to the paper's
post-processing, §4.5).

Work O(N·K + N log N), depth O(K + log N).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .frontier import scatter_set_dense

__all__ = ["RandHKPRResult", "rand_hk_pr", "poisson_cdf_table"]


def poisson_cdf_table(t: float, K: int) -> np.ndarray:
    """CDF of Poisson(t) truncated to [0, K] (all tail mass at K)."""
    pmf = np.array([math.exp(-t) * t ** k / math.factorial(k)
                    for k in range(K + 1)], dtype=np.float64)
    pmf[-1] += max(0.0, 1.0 - pmf.sum())
    return np.cumsum(pmf / pmf.sum())


class RandHKPRResult(NamedTuple):
    ids: jnp.ndarray     # int32[num_walks] — unique destination vertices (sentinel-padded)
    vals: jnp.ndarray    # f32[num_walks]  — probability mass (count / N)
    nnz: jnp.ndarray     # int32 — number of unique destinations
    dests: jnp.ndarray   # int32[num_walks] — raw walk destinations (the array A)


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def rand_hk_pr(graph: CSRGraph, x, num_walks: int, K: int, t: float,
               key: jax.Array = None) -> RandHKPRResult:
    """All walks in parallel (vmapped scan), then sort+prefix-sum histogram."""
    if key is None:
        key = jax.random.PRNGKey(0)
    n = graph.n
    deg = graph.deg
    cdf = jnp.asarray(poisson_cdf_table(t, K), jnp.float32)

    klen_key, walk_key = jax.random.split(key)
    u = jax.random.uniform(klen_key, (num_walks,))
    lengths = jnp.searchsorted(cdf, u).astype(jnp.int32)  # walk lengths

    step_keys = jax.random.split(walk_key, K)

    def one_step(carry, step_key):
        v, step, length = carry
        # uniform neighbor: indices[indptr[v] + floor(U * d(v))]
        d = deg[v]
        us = jax.random.uniform(step_key, (num_walks,))
        off = jnp.floor(us * d).astype(jnp.int32)
        off = jnp.clip(off, 0, jnp.maximum(d - 1, 0))
        nxt = graph.indices[jnp.clip(graph.indptr[v] + off, 0,
                                     graph.indices.shape[0] - 1)]
        move = (step < length) & (d > 0)
        v = jnp.where(move, nxt, v)
        return (v, step + 1, length), None

    v0 = jnp.full((num_walks,), jnp.asarray(x, jnp.int32))
    (dest, _, _), _ = jax.lax.scan(
        one_step, (v0, jnp.zeros((num_walks,), jnp.int32), lengths), step_keys)

    # paper §4.5 histogram: sort A; B[i]=i where A[i]!=A[i-1]; filter; diff
    a = jnp.sort(dest)
    first = jnp.concatenate([jnp.array([True]), a[1:] != a[:-1]])
    nnz = jnp.sum(first).astype(jnp.int32)
    pos = jnp.cumsum(first) - 1                       # output slot per group
    ids = scatter_set_dense(jnp.full((num_walks,), n, dtype=jnp.int32),
                            pos, a, first)
    # counts via difference of group start offsets
    offsets = jnp.full((num_walks + 1,), num_walks, dtype=jnp.int32)
    offsets = scatter_set_dense(offsets, pos,
                                jnp.arange(num_walks, dtype=jnp.int32), first)
    offsets = scatter_set_dense(offsets, jnp.minimum(nnz, num_walks),
                                num_walks, True)
    counts = offsets[1:] - offsets[:-1]
    valid = jnp.arange(num_walks) < nnz
    vals = jnp.where(valid, counts, 0).astype(jnp.float32) / num_walks
    return RandHKPRResult(ids=ids, vals=vals, nnz=nnz, dests=dest)
