"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch × shape × mesh) cell, all PER-CHIP (XLA cost analysis
reports the post-SPMD per-device module — verified against a hand-counted
sharded matmul):

    compute_s    = HLO_FLOPs_per_chip      / peak_FLOPs      (197 TF/s bf16)
    memory_s     = HLO_bytes_per_chip      / HBM_bw          (819 GB/s)
    collective_s = collective_bytes_per_chip / link_bw       (~50 GB/s/link)

``collective_bytes`` is not in cost_analysis: we parse the optimized HLO,
build an instruction→shape table, and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(the brief's definition).  An effective ring-model estimate
(×2(g−1)/g for all-reduce etc.) is also recorded for reference.

MODEL_FLOPS uses 6·N·D for training steps and 2·N·D for inference steps
(N = active params, D = global tokens); the ratio MODEL_FLOPS/HLO_FLOPs
exposes remat recompute and dispatch overheads.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "cost_analysis_dict", "roofline_report"]


def cost_analysis_dict(compiled) -> Dict:
    """``compiled.cost_analysis()`` across jax versions: older jaxlibs return
    a one-dict-per-device list, newer ones a flat dict.  Normalize to a dict
    (the per-chip module is identical post-SPMD, so device 0 suffices)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 per chip (TPU v5e-class)
    hbm_bw: float = 819e9           # bytes/s per chip
    link_bw: float = 50e9           # bytes/s per ICI link
    hbm_bytes: float = 16e9         # capacity per chip


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)", re.M)
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    # instruction name -> result shape string
    shapes: Dict[str, str] = {}
    for m in _INSTR_RE.finditer(hlo_text):
        shapes[m.group(1)] = m.group(2)

    out = {k: 0.0 for k in _COLLECTIVES}
    out_effective = {k: 0.0 for k in _COLLECTIVES}
    group_re = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        # operand names inside parens
        paren = line[line.find("(") + 1: line.rfind(")")]
        opnd_names = re.findall(r"%?([\w\.\-]+)", paren)
        obytes = 0
        for name in opnd_names:
            if name in shapes:
                obytes += _shape_bytes(shapes[name])
        if obytes == 0:
            # fall back to result shape (covers inline-typed operand format)
            obytes = _shape_bytes(m.group(2))
            if base == "all-gather":
                gm = group_re.search(line)
                if gm:
                    g = len(gm.group(1).split(","))
                    obytes = obytes // max(g, 1)
        out[base] += obytes
        # ring-model effective bytes
        gm = group_re.search(line)
        g = len(gm.group(1).split(",")) if gm else 2
        frac = (g - 1) / max(g, 1)
        eff = {"all-reduce": 2 * frac, "all-gather": frac,
               "reduce-scatter": frac, "all-to-all": frac,
               "collective-permute": 1.0}[base]
        out_effective[base] += obytes * eff
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["total_effective"] = sum(out_effective[k] for k in _COLLECTIVES)
    return out


def roofline_report(compiled, *, hw: HW = HW(), chips: int,
                    model_flops: Optional[float] = None,
                    hlo_text: Optional[str] = None) -> Dict:
    from .hlocost import analyze_hlo
    ca = cost_analysis_dict(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # trip-count-aware walker (hlocost.py): XLA's cost_analysis counts scan
    # bodies once; the walker multiplies by known_trip_count.
    walk = analyze_hlo(text)
    flops = float(walk.flops)
    bytes_accessed = float(walk.bytes)
    mem = compiled.memory_analysis()
    report = {
        "per_chip_flops": flops,
        "per_chip_bytes": bytes_accessed,
        "xla_cost_flops_unscaled": float(ca.get("flops", 0.0)),
        "xla_cost_bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes": float(walk.collective_bytes),
        "collective_breakdown": dict(walk.collective_breakdown),
        "dynamic_trip_loops": walk.dynamic_loops,
        "compute_s": flops / hw.peak_flops,
        "memory_s": bytes_accessed / hw.hbm_bw,
        "collective_s": float(walk.collective_bytes) / hw.link_bw,
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        # donated outputs alias inputs — don't double count them
        "peak_hbm_frac": (mem.argument_size_in_bytes +
                          mem.temp_size_in_bytes +
                          mem.output_size_in_bytes -
                          mem.alias_size_in_bytes) / hw.hbm_bytes,
        "num_chips": chips,
    }
    terms = {k: report[k] for k in ("compute_s", "memory_s", "collective_s")}
    report["bottleneck"] = max(terms, key=terms.get)
    report["step_time_lower_bound_s"] = max(terms.values())
    if model_flops:
        report["model_flops"] = model_flops
        report["useful_flops_ratio"] = model_flops / max(flops * chips, 1.0)
        report["roofline_fraction"] = (
            (model_flops / chips / hw.peak_flops) /
            max(report["step_time_lower_bound_s"], 1e-30))
    return report
