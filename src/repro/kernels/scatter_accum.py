"""Sorted-bucket scatter-add Pallas kernel — fetchAdd on the MXU.

The paper replaces sequential updates with atomic ``fetchAdd``; XLA replaces
atomics with ``scatter-add``.  On TPU, scatter lowers to a serialized update
loop — the hot-spot the paper's algorithms hammer hardest (every EDGEMAP ends
in one).  This kernel restructures it:

  1. (wrapper, ops.py) sort contributions by destination, bucket them into
     128-wide destination tiles, pad each bucket to a fixed chunk ``C``;
  2. (kernel) for each tile: build the (C × 128) one-hot of local offsets and
     accumulate ``vals[1, C] @ onehot[C, 128]`` on the MXU — turning O(C)
     serialized memory updates into one systolic contraction.

Duplicate destinations need no special casing: their one-hot rows share a
column and the matmul sums them — exactly the associativity argument the
paper uses for fetchAdd.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["scatter_accum_tiles", "TILE"]

TILE = 128


def _scatter_kernel(local_ref, vals_ref, out_ref):
    """One destination tile: out[128] = Σ_j vals[j] · onehot(local[j])."""
    C = local_ref.shape[1]
    local = local_ref[0, :]                 # int32[C] in [0, 128) or -1 (pad)
    vals = vals_ref[0, :]                   # f32[C]
    iota = jax.lax.broadcasted_iota(jnp.int32, (C, TILE), 1)
    onehot = (iota == local.reshape(C, 1)).astype(jnp.float32)
    acc = jax.lax.dot_general(
        vals.reshape(1, C), onehot, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    out_ref[0, :] = acc.reshape(TILE)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_accum_tiles(local: jnp.ndarray, vals: jnp.ndarray,
                        interpret: bool = False) -> jnp.ndarray:
    """Per-tile accumulation.

    Args:
      local: int32[T, C] — local destination offsets (0..127) within each of
             T tiles; padding entries must be -1 (or any value outside 0..127).
      vals:  f32[T, C]   — contribution values (0 at padding).
    Returns:
      f32[T, 128] — accumulated tile updates (caller adds into the dense
      vector with one contiguous reshape-add).
    """
    T, C = local.shape
    return pl.pallas_call(
        _scatter_kernel,
        out_shape=jax.ShapeDtypeStruct((T, TILE), jnp.float32),
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, C), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda i: (i, 0)),
        interpret=interpret,
    )(local, vals)
