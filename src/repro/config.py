"""Framework configuration system.

One dataclass describes every assigned architecture (dense GQA, MoE, SSM,
RG-LRU hybrid, enc-dec, modality-stub VLM/audio) plus the training/serving
shapes.  Configs are plain data — hashable, printable, serializable — and
the model builder (`repro.models.model`) consumes nothing else.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "MeshConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads

    # per-layer mixer pattern, cycled: e.g. ("attn",) for pure dense,
    # ("attn_local",)*5 + ("attn_global",) for gemma3,
    # ("rglru", "rglru", "attn_local") for recurrentgemma,
    # ("mamba2",) for mamba2.
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 1024                      # sliding window for *_local
    rope_theta: float = 10_000.0

    # feed-forward
    ff_kind: str = "swiglu"                 # "swiglu" | "moe" | "none"
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_per_row: bool = False               # per-batch-row (shard-local) dispatch

    # SSM (mamba2)
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # RG-LRU
    rglru_conv_width: int = 4

    # enc-dec (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                     # audio frames after conv stub

    # modality stub: prepend precomputed frontend embeddings
    modality: Optional[str] = None          # None | "audio" | "vision"
    n_modality_tokens: int = 0              # e.g. vision patches

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # attention chunking (flash-style scan block sizes)
    q_chunk: int = 512
    kv_chunk: int = 1024

    # notes from the source config (provenance)
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_expert(self) -> int:
        """Per-expert hidden width (MoE archs list d_ff as per-expert)."""
        return self.d_ff

    def param_count(self) -> int:
        """Approximate parameter count (for 6·N·D model-FLOPs)."""
        d, v, L = self.d_model, self.vocab, self.n_layers
        hd = self.head_dim_
        total = v * d  # embedding (tied output head)
        pattern = self.layer_pattern
        for i in range(L):
            kind = pattern[i % len(pattern)]
            if kind.startswith("attn"):
                total += d * self.n_heads * hd + d * 2 * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            elif kind == "mamba2":
                d_in = self.ssm_expand * d
                total += d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            elif kind == "rglru":
                total += 3 * d * self.d_ff_rnn + 2 * self.d_ff_rnn * d
            if self.ff_kind == "swiglu":
                total += 3 * d * self.d_ff
            elif self.ff_kind == "moe":
                total += 3 * d * self.d_expert * self.n_experts + d * self.n_experts
        if self.enc_dec:
            for _ in range(self.n_enc_layers):
                total += 4 * d * self.n_heads * hd + 3 * d * self.d_ff
                total += 4 * d * self.n_heads * hd  # cross-attn in decoder
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.ff_kind != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.param_count()
        total -= 3 * d * self.d_expert * self.n_experts * L
        total += 3 * d * self.d_expert * max(self.top_k, 1) * L
        return total

    @property
    def d_ff_rnn(self) -> int:
        """RG-LRU recurrent width (recurrentgemma uses d_model-width RNN)."""
        return self.d_model


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out
