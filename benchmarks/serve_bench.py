"""Serving-latency benchmark: deadline scheduler under a Poisson stream.

The serving claim is different from the throughput claims of
`batched_bench.py`: here requests *arrive over time* (Poisson process), each
with a latency budget, and the metric is the request-latency distribution —
p50/p95/p99 — plus the deadline-miss rate, per lane backend (dense vs
sparse).  The `AsyncClusterEngine` runs in its background drive thread while
this process plays an open-loop arrival schedule at it, the standard
serving-benchmark shape.

Warmup is measured *separately* from steady state: each lane first runs
``LocalClusterEngine.warmup`` (AOT-compiling every tick executable the
stream can touch) plus one priming request, reported as the lane's
``warmup_ms`` (and its own ``*_warmup`` CSV row) — the timed Poisson stream
then measures pure serving behavior, never compile time.

The seed mix is serving-shaped: a hot set of repeated seeds (70% of
arrivals) over a uniform cold tail — hot queries repeat in real streams,
which is exactly what the engine's versioned seed→result cache exploits;
the artifact reports the resulting ``cache_hit_rate`` alongside the latency
distribution.

``--characterize`` runs a deterministic no-deadline sweep instead and
writes ``benchmarks/baselines/tick_costs.json`` — measured per-pool tick
costs that seed the EDF planner's cost model (its cold-start fix: without
it a never-ticked pool is costed by a guess exactly when deadlines are
tightest).  The normal benchmark auto-loads that file when present.

Emits the usual `name,us_per_call,derived` CSV rows (us = p50 latency) and
returns a JSON-able dict that `benchmarks/run.py` writes to
``BENCH_serve.json`` — the artifact CI uploads so the serving-latency
trajectory accumulates across PRs.

``--trace`` additionally flight-records every request through a
:class:`repro.serve.tracing.Tracer` and writes ``BENCH_trace.json``:
Chrome trace events (load in Perfetto), a per-request phase-attribution
table (queued / pool_queue / resident / sweep / deliver, with coverage =
how much of the measured wall latency the spans explain), the
deadline-miss postmortems from the telemetry snapshot, and a purity probe
asserting the traced stream is bit-identical to an untraced one.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.serve import (AsyncClusterEngine, ClusterRequest,
                         LocalClusterEngine, MetricsRegistry, Tracer)
from repro.serve.telemetry import pool_label
from repro.serve.tracing import TRACE_SCHEMA
from .common import get_graph, emit

TICK_COSTS_SCHEMA = "repro.bench.tick_costs/v1"
TICK_COSTS_PATH = os.path.join(os.path.dirname(__file__), "baselines",
                               "tick_costs.json")


def _percentiles(lat_ms):
    lat = np.sort(np.asarray(lat_ms, np.float64))
    pick = lambda q: float(lat[min(len(lat) - 1,
                                   int(round(q / 100 * (len(lat) - 1))))])
    return dict(p50_ms=pick(50), p95_ms=pick(95), p99_ms=pick(99))


def _request_stream(graph, rng, n_requests: int, hot_seeds: int = 16,
                    hot_fraction: float = 0.75,
                    alphas: tuple = (0.05, 0.02)):
    """Serving-shaped request mix: ``hot_fraction`` of arrivals draw their
    seed from a small hot set (repeated queries — the result cache's
    regime), the rest uniformly from every non-isolated vertex.  α is a
    deterministic function of the seed so a hot seed's repeats share one
    cache identity — real streams re-ask the *same* query, they don't
    re-roll its knobs."""
    cand = np.flatnonzero(np.asarray(graph.deg) > 0)
    hot = rng.choice(cand, size=min(hot_seeds, len(cand)), replace=False)
    seeds = np.where(rng.random(n_requests) < hot_fraction,
                     rng.choice(hot, size=n_requests),
                     rng.choice(cand, size=n_requests)).astype(np.int64)
    return [ClusterRequest(seed=int(s),
                           alpha=float(alphas[int(s) % len(alphas)]),
                           eps=1e-4)
            for s in seeds]


def _run_lane(graph, backend: str, n_requests: int, mean_gap_s: float,
              deadline_ms: float, batch_slots: int, caps: dict,
              seed: int = 0, tracer=None, telemetry=None,
              cost_table=None, stream_kw: dict = None) -> dict:
    """Play one Poisson-arrival stream at a fresh scheduler; returns the
    latency/miss summary for the BENCH_serve.json artifact.  With a
    ``tracer`` the summary also carries per-request phase attribution,
    Chrome trace events, and the telemetry postmortems."""
    rng = np.random.default_rng(seed)
    reqs = _request_stream(graph, rng, n_requests, **(stream_kw or {}))
    gaps = rng.exponential(mean_gap_s, size=n_requests)
    engine = LocalClusterEngine(graph, batch_slots=batch_slots,
                                backend=backend, **caps)
    # Warmup, measured apart from the stream: AOT-compile the tick
    # executables of buckets 0..1 (every shape this stream promotes into),
    # then prime each pool with one untimed request so the first *tick*
    # (pool/state allocation, dist jits) is also off the clock.
    t0 = time.perf_counter()
    engine.warmup([ClusterRequest(seed=0, alpha=0.05, eps=1e-4)],
                  max_bucket=1)
    telem = telemetry if telemetry is not None else MetricsRegistry()
    sched = AsyncClusterEngine(engine, max_queue=4 * n_requests,
                               tracer=tracer, telemetry=telem,
                               cost_table=cost_table)
    with sched:
        sched.submit(ClusterRequest(seed=int(reqs[0].seed), alpha=0.05,
                                    eps=1e-4)).result(timeout=300.0)
        warmup_ms = (time.perf_counter() - t0) * 1e3
        # scheduler-level hits resolve through engine.cached_result, so the
        # engine counter already covers both the pre-admission fast path
        # and hits discovered at admission time
        hits0 = engine.stats["result_cache_hits"]
        t0 = time.perf_counter()
        futs = []
        for req, gap in zip(reqs, gaps):
            time.sleep(float(gap))      # open-loop: arrivals don't wait
            futs.append(sched.submit(req, deadline_ms=deadline_ms))
        results = [f.result(timeout=300.0) for f in futs]
        wall_s = time.perf_counter() - t0
        hits = engine.stats["result_cache_hits"] - hits0
    lat_ms = [f.latency_ms for f in futs]
    missed = sum(r.deadline_missed for r in results)
    out = _percentiles(lat_ms)
    out.update(
        deadline_miss_rate=missed / n_requests,
        n_requests=n_requests,
        deadline_ms=deadline_ms,
        mean_gap_ms=mean_gap_s * 1e3,
        wall_s=wall_s,
        throughput_rps=n_requests / wall_s,
        backend=backend,
        warmup_ms=warmup_ms,
        aot_compiles=engine.stats["aot_compiles"],
        aot_compile_s=engine.stats["aot_compile_s"],
        cache_hit_rate=hits / n_requests,
        status_syncs=engine.stats["status_syncs"],
    )
    if tracer is not None:
        recs = []
        for f, r in zip(futs, results):
            s = f.trace.summary()
            s["deadline_missed"] = bool(r.deadline_missed)
            # coverage against the *scheduler-measured* wall latency, the
            # number the artifact reports (the root span tracks it to µs)
            if f.latency_ms:
                s["coverage"] = min(1.0, sum(s["phases_ms"].values())
                                    / f.latency_ms)
            recs.append(s)
        out["requests"] = recs
        covs = [s["coverage"] for s in recs if s["coverage"] is not None]
        out["coverage_min"] = min(covs) if covs else None
        out["coverage_mean"] = (sum(covs) / len(covs)) if covs else None
        out["events"] = tracer.chrome_trace()
        out["spans_dropped"] = tracer.dropped
        out["postmortems"] = telem.postmortems()
    return out


def _purity_probe(graph, batch_slots: int, caps: dict, n: int = 8) -> dict:
    """Deterministic traced-vs-untraced comparison (guarantee #8): the same
    request list through two fresh engines, one flight-recorded, one not —
    every result field must agree bitwise.  Single-threaded and deadline-
    free so the comparison is exact, not timing-dependent."""
    rng = np.random.default_rng(7)
    seeds = rng.choice(np.flatnonzero(np.asarray(graph.deg) > 0), size=n)
    reqs = [ClusterRequest(seed=int(s), alpha=0.05, eps=1e-4) for s in seeds]
    traced = LocalClusterEngine(graph, batch_slots=batch_slots,
                                tracer=Tracer(), **caps).run(reqs)
    plain = LocalClusterEngine(graph, batch_slots=batch_slots,
                               **caps).run(reqs)
    identical = all(
        a.conductance == b.conductance and a.size == b.size
        and a.volume == b.volume and a.support == b.support
        and a.pushes == b.pushes and a.iterations == b.iterations
        and np.array_equal(a.cluster, b.cluster)
        for a, b in zip(traced, plain))
    return dict(n_requests=n, bit_identical=identical)


def _smoke_config() -> dict:
    """The CI tier: 256 Poisson requests against the planted SBM, sized so
    warm steady-state ticks are tens of ms (narrow batch, small
    workspaces, 8-round ticks keep per-request latency ≈ iters × per-round
    cost) and the p99 clears the 1 s deadline.  The sparse lane serves the
    α=0.05 slice only — its per-round cost is ~3× dense, so the deep
    α=0.02 walks (83 iterations) belong to the dense lane."""
    return dict(
        name="sbm-planted", n_requests=256, mean_gap_s=0.07,
        deadline_ms=1000.0, batch_slots=4,
        caps=dict(cap_f=1 << 9, cap_e=1 << 12, cap_n=1 << 10,
                  sweep_cap_e=1 << 13, cap_v=1 << 10, rounds_per_step=8),
        lane_streams=dict(
            dense=dict(alphas=(0.05, 0.02), hot_fraction=0.85),
            sparse=dict(alphas=(0.05,), hot_fraction=0.85)))


def _full_config() -> dict:
    return dict(
        name="randLocal-50k", n_requests=64, mean_gap_s=0.005,
        deadline_ms=250.0, batch_slots=8, caps={})


def characterize(smoke: bool = False,
                 path: str = TICK_COSTS_PATH) -> dict:
    """Measure steady-state tick cost per pool (deterministic, no deadlines,
    no Poisson) and write the ``tick_costs.json`` baseline the EDF planner
    seeds its cost model from.  Entries: exact pool labels, plus the
    ``"method:backend"`` family averages the planner falls back to for
    never-characterized buckets."""
    cfg = _smoke_config() if smoke else _full_config()
    graph = get_graph(cfg["name"])
    rng = np.random.default_rng(11)
    entries: dict = {}
    families: dict = {}
    for backend in ("dense", "sparse"):
        engine = LocalClusterEngine(graph, batch_slots=cfg["batch_slots"],
                                    backend=backend, lru_pools=16,
                                    **cfg["caps"])
        engine.warmup([ClusterRequest(seed=0, alpha=0.05, eps=1e-4)],
                      max_bucket=1)
        engine.run(_request_stream(graph, rng, 24))
        for key, pool in engine.pools.items():
            if pool.cost_ema is None:
                continue
            entries[pool_label(key)] = pool.cost_ema
            families.setdefault(f"{key[0]}:{key[1]}", []).append(
                pool.cost_ema)
    for fam, costs in families.items():
        entries[fam] = sum(costs) / len(costs)
    doc = dict(schema=TICK_COSTS_SCHEMA, graph=cfg["name"],
               smoke=smoke, generated_unix=time.time(),
               rounds_per_step=cfg["caps"].get("rounds_per_step", 16),
               entries=entries)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    print(f"wrote {path} ({len(entries)} entries)", flush=True)
    return doc


def run(smoke: bool = False, trace: bool = False,
        requests: int = None) -> dict:
    cfg = _smoke_config() if smoke else _full_config()
    if requests is not None:
        cfg["n_requests"] = requests
    graph = get_graph(cfg["name"])
    cost_table = TICK_COSTS_PATH if os.path.exists(TICK_COSTS_PATH) else None
    artifact = dict(graph=cfg["name"], smoke=smoke, lanes={})
    traced_lanes = {}
    for backend in ("dense", "sparse"):
        tracer = Tracer(capacity=1 << 16) if trace else None
        telemetry = MetricsRegistry() if trace else None
        lane = _run_lane(graph, backend, cfg["n_requests"],
                         cfg["mean_gap_s"], cfg["deadline_ms"],
                         batch_slots=cfg["batch_slots"], caps=cfg["caps"],
                         tracer=tracer, telemetry=telemetry,
                         cost_table=cost_table,
                         stream_kw=cfg.get("lane_streams", {}).get(backend))
        if trace:
            # the trace payload goes to BENCH_trace.json, not BENCH_serve
            traced_lanes[backend] = {
                k: lane.pop(k) for k in ("requests", "events", "postmortems",
                                         "coverage_min", "coverage_mean",
                                         "spans_dropped")}
            traced_lanes[backend]["deadline_miss_rate"] = \
                lane["deadline_miss_rate"]
        artifact["lanes"][backend] = lane
        emit(f"serve/{cfg['name']}/{backend}_poisson_B={cfg['n_requests']}",
             lane["p50_ms"] * 1e3,
             f"p95_ms={lane['p95_ms']:.1f};p99_ms={lane['p99_ms']:.1f};"
             f"miss_rate={lane['deadline_miss_rate']:.3f};"
             f"rps={lane['throughput_rps']:.1f};"
             f"cache_hit_rate={lane['cache_hit_rate']:.3f}")
        emit(f"serve/{cfg['name']}/{backend}_warmup",
             lane["warmup_ms"] * 1e3,
             f"aot_compiles={lane['aot_compiles']};"
             f"aot_compile_s={lane['aot_compile_s']:.2f}")
    if trace:
        # one Perfetto-loadable event stream: lanes separated by pid
        events = []
        for pid, (backend, tl) in enumerate(traced_lanes.items()):
            for ev in tl.pop("events"):
                events.append(dict(ev, pid=pid))
        trace_artifact = dict(
            schema=TRACE_SCHEMA, suite="serve_trace", smoke=smoke,
            generated_unix=time.time(), graph=cfg["name"],
            deadline_ms=cfg["deadline_ms"],
            purity=_purity_probe(graph, cfg["batch_slots"], cfg["caps"]),
            lanes=traced_lanes,
            traceEvents=events)
        with open("BENCH_trace.json", "w") as f:
            json.dump(trace_artifact, f, indent=2, sort_keys=True)
        print("wrote BENCH_trace.json", flush=True)
        artifact["trace_artifact"] = "BENCH_trace.json"
    return artifact


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", action="store_true",
                    help="flight-record every request; write BENCH_trace.json")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the stream length (default: 256 smoke / "
                         "64 full)")
    ap.add_argument("--characterize", action="store_true",
                    help="measure per-pool tick costs and write "
                         "benchmarks/baselines/tick_costs.json instead of "
                         "running the Poisson benchmark")
    args = ap.parse_args()
    if args.characterize:
        print(json.dumps(characterize(smoke=args.smoke), indent=2))
    else:
        print(json.dumps(run(smoke=args.smoke, trace=args.trace,
                             requests=args.requests), indent=2))
