"""Parallel PR-Nibble (paper §4.3, Figures 3–4) — approximate personalized
PageRank by synchronous parallel push.

Each round pushes from *every* vertex with ``r[v] ≥ d(v)·ε`` simultaneously,
reading the residual ``r`` frozen at the start of the round and accumulating
into the double buffer ``r'`` (the paper's race-free design; the asynchronous
single-buffer variant leaks mass and is explicitly rejected in §4.3).

Two update rules:
  * ``original``  (Fig 3):  p[v] += α·r[v];           r'[v] = (1−α)·r[v]/2;
                            r'[w] += (1−α)·r[v]/(2d(v))
  * ``optimized`` (Fig 4):  p[v] += 2α/(1+α)·r[v];    r'[v] = 0;
                            r'[w] += (1−α)/(1+α)·r[v]/d(v)
    (optimal coordinate-descent step size — same conductance guarantee,
    1.4–6.4× less work in the paper's Fig 2.)

Work O(1/(αε)) for either rule (Theorem 3) — independent of round count.

Beyond the paper: a ``beta`` knob selects only the top β-fraction of
above-threshold vertices by r[v]/d(v) each round (the paper's work/parallelism
trade-off variant, reported but not detailed there).

Backends:
  * dense  — state vectors are dense f32[n]; per-round *work* is still
             O(vol(frontier)) (all gathers/scatters are frontier-sized).
  * sparse — `SparseVec` sort-merge sparse sets (see sparsevec.py): true
             O(|support|) memory, the faithful analogue of the paper's
             concurrent hash table.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .frontier import (Frontier, expand, pack_unique, singleton, seed_set,
                       scatter_add_dense, scatter_set_dense, one_hot_f32)

__all__ = ["PRNibbleResult", "PRNibbleState", "pr_nibble", "pr_nibble_fixedcap",
           "pr_nibble_init", "pr_nibble_round", "pr_nibble_alive", "MAX_ITERS"]

# Round budget shared by every driver that must stay bit-identical to this
# one (core/batched.py, serve/cluster_engine.py import it).
MAX_ITERS = 10_000


class PRNibbleResult(NamedTuple):
    p: jnp.ndarray           # f32[n]
    r: jnp.ndarray           # f32[n] — final residual
    iterations: jnp.ndarray  # int32
    pushes: jnp.ndarray      # int32  (Table 1 counter)
    edge_work: jnp.ndarray   # int32
    overflow: jnp.ndarray    # bool


class PRNibbleState(NamedTuple):
    """Loop carry of one PR-Nibble run — exposed so batched/streaming drivers
    (core/batched.py, serve/cluster_engine.py) can step the same rounds."""
    p: jnp.ndarray
    r: jnp.ndarray
    frontier: Frontier
    t: jnp.ndarray
    pushes: jnp.ndarray
    edge_work: jnp.ndarray
    overflow: jnp.ndarray


def pr_nibble_init(x, n: int, cap_f: int) -> PRNibbleState:
    """Initial state: unit residual mass on the seed (or 1/k per seed-set
    vertex, paper footnote 3) and the seed frontier."""
    if isinstance(x, tuple):
        seeds, count = x
        seeds = jnp.asarray(seeds, jnp.int32)
        valid = jnp.arange(seeds.shape[0]) < count
        r0 = scatter_add_dense(jnp.zeros((n,), jnp.float32), seeds,
                               jnp.full(seeds.shape, 1.0 / count, jnp.float32),
                               valid)
        front0 = seed_set(seeds, count, n, cap_f)
    else:
        r0 = one_hot_f32(x, n)
        front0 = singleton(x, n, cap_f)
    return PRNibbleState(p=jnp.zeros((n,), jnp.float32), r=r0,
                         frontier=front0,
                         t=jnp.asarray(0, jnp.int32),
                         pushes=jnp.asarray(0, jnp.int32),
                         edge_work=jnp.asarray(0, jnp.int32),
                         overflow=jnp.asarray(False))


def pr_nibble_alive(s: PRNibbleState, max_iters: int = MAX_ITERS) -> jnp.ndarray:
    """True while the run still has above-threshold residual to push."""
    return (s.frontier.count > 0) & (~s.overflow) & (s.t < max_iters)


def pr_nibble_round(graph: CSRGraph, s: PRNibbleState, eps, alpha,
                    optimized: bool, cap_e: int,
                    beta: float = 1.0, backend: str = "xla") -> PRNibbleState:
    """One synchronous push round (the while-loop body of Figures 3–4).

    ``backend`` selects the kernel backend for every scatter/scan in the
    round (see :mod:`repro.core.ops`); results are bit-identical across
    backends (interpret mode off-TPU)."""
    n = graph.n
    deg = graph.deg
    f = s.frontier
    fvalid = f.valid()
    fids = jnp.where(fvalid, f.ids, n)
    safe = jnp.minimum(fids, n - 1)
    all_fids, all_fvalid = fids, fvalid  # full frontier (pre-β) for re-filter

    if beta < 1.0:
        # β-selection: push only the top β-fraction by r/d (paper's
        # work-vs-parallelism trade-off variant)
        r_over_d = jnp.where(fvalid, s.r[safe] / jnp.maximum(deg[safe], 1),
                             -jnp.inf)
        k = jnp.maximum(jnp.ceil(beta * f.count), 1.0).astype(jnp.int32)
        kth = -jnp.sort(-r_over_d)[jnp.minimum(k - 1, f.cap - 1)]
        sel = fvalid & (r_over_d >= kth)
        # re-pack: Frontier validity is prefix-based, so the selected ids
        # must be compacted to the front
        f = pack_unique(fids, sel, n, f.cap, backend=backend)
        fvalid = f.valid()
        fids = jnp.where(fvalid, f.ids, n)
        safe = jnp.minimum(fids, n - 1)

    rf = jnp.where(fvalid, s.r[safe], 0.0)
    dv = jnp.maximum(deg[safe], 1)

    if optimized:
        p_gain = (2.0 * alpha / (1.0 + alpha)) * rf
        r_self = jnp.zeros_like(rf)
        share = ((1.0 - alpha) / (1.0 + alpha)) * rf / dv
    else:
        p_gain = alpha * rf
        r_self = (1.0 - alpha) * rf / 2.0
        share = (1.0 - alpha) * rf / (2.0 * dv)

    p_new = scatter_add_dense(s.p, fids, p_gain, fvalid, backend=backend)
    # r' starts as r with frontier entries replaced (double buffer)
    r_new = scatter_set_dense(s.r, fids, r_self, fvalid)

    eb = expand(graph, f, cap_e, backend=backend)
    contrib = share[eb.slot]
    r_new = scatter_add_dense(r_new, eb.dst, contrib, eb.valid,
                              backend=backend)

    cands = jnp.concatenate([all_fids, eb.dst])
    cvalid = jnp.concatenate([all_fvalid, eb.valid])
    csafe = jnp.minimum(cands, n - 1)
    keep = cvalid & (deg[csafe] > 0) & (r_new[csafe] >= deg[csafe] * eps)
    nf = pack_unique(cands, keep, n, s.frontier.cap, backend=backend)

    return PRNibbleState(p=p_new, r=r_new, frontier=nf, t=s.t + 1,
                         pushes=s.pushes + f.count,
                         edge_work=s.edge_work + eb.total,
                         overflow=s.overflow | nf.overflow | eb.overflow)


@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8),
                   static_argnames=("optimized", "cap_f", "cap_e",
                                    "max_iters", "beta", "backend"))
def pr_nibble_fixedcap(graph: CSRGraph, x, eps, alpha,
                       optimized: bool, cap_f: int, cap_e: int,
                       max_iters: int = MAX_ITERS, beta: float = 1.0, *,
                       backend: str = "xla") -> PRNibbleResult:
    def cond(s: PRNibbleState):
        return pr_nibble_alive(s, max_iters)

    def body(s: PRNibbleState) -> PRNibbleState:
        return pr_nibble_round(graph, s, eps, alpha, optimized, cap_e, beta,
                               backend)

    s = jax.lax.while_loop(cond, body, pr_nibble_init(x, graph.n, cap_f))
    return PRNibbleResult(p=s.p, r=s.r, iterations=s.t, pushes=s.pushes,
                          edge_work=s.edge_work, overflow=s.overflow)


def pr_nibble(graph: CSRGraph, x, eps: float = 1e-7, alpha: float = 0.01,
              optimized: bool = True, cap_f: int = 1 << 12, cap_e: int = 1 << 16,
              max_cap_e: int = 1 << 26, beta: float = 1.0,
              backend: str = "xla") -> PRNibbleResult:
    """Bucketed driver: retry with doubled capacities on overflow."""
    while True:
        out = pr_nibble_fixedcap(graph, x, eps, alpha, optimized, cap_f, cap_e,
                                 beta=beta, backend=backend)
        if not bool(out.overflow) or cap_e >= max_cap_e:
            return out
        cap_f = min(cap_f * 2, graph.n + 1)
        cap_e = cap_e * 2
