#!/usr/bin/env python
"""CI gate: docs must not rot.

Checks, over ``docs/*.md`` and ``README.md``:

  * every relative markdown link ``[text](target)`` resolves to an existing
    file (http/mailto links are skipped), and its ``#fragment`` — if any —
    matches a heading in the target file (GitHub slug rules, simplified);
  * every backtick code reference that looks like a repo path
    (``src/repro/core/batched.py``, ``benchmarks/run.py``, ``docs/x.md`` …)
    points at an existing file, trying repo root, the doc's own directory,
    and ``src/repro/`` as bases;
  * every backtick dotted reference starting with ``repro.`` resolves to a
    module or an attribute exported by one (so renames break the build,
    not the reader).

Exit 0 when clean; exit 1 listing every broken reference.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted((REPO / "docs").glob("*.md")) + [REPO / "README.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(
    r"`([A-Za-z0-9_./-]+\.(?:py|md|yml|yaml|toml|txt|csv))`")
DOTTED_RE = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (simplified: enough for our headings)."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def headings(md: Path) -> set:
    out = set()
    for line in md.read_text().splitlines():
        m = re.match(r"#+\s+(.*)", line)
        if m:
            out.add(slugify(m.group(1)))
    return out


def check_link(doc: Path, target: str, errors: list) -> None:
    if target.startswith(("http://", "https://", "mailto:")):
        return
    path, _, frag = target.partition("#")
    dest = doc if not path else (doc.parent / path).resolve()
    if not dest.exists():
        errors.append(f"{doc.relative_to(REPO)}: broken link -> {target}")
        return
    if frag and dest.suffix == ".md" and slugify(frag) not in headings(dest):
        errors.append(f"{doc.relative_to(REPO)}: missing anchor -> {target}")


def check_path_ref(doc: Path, ref: str, errors: list) -> None:
    if "/" not in ref:        # bare filenames ("run.py") aren't repo claims
        return
    for base in (REPO, doc.parent, REPO / "src" / "repro"):
        if (base / ref).exists():
            return
    errors.append(f"{doc.relative_to(REPO)}: missing code ref -> {ref}")


def check_dotted_ref(doc: Path, ref: str, errors: list) -> None:
    parts = ref.split(".")
    # longest prefix that is a module file/package under src/
    for cut in range(len(parts), 0, -1):
        mod = REPO / "src" / Path(*parts[:cut])
        if mod.with_suffix(".py").exists() or (mod / "__init__.py").exists():
            src = (mod.with_suffix(".py") if mod.with_suffix(".py").exists()
                   else mod / "__init__.py")
            rest = parts[cut:]
            if not rest or re.search(
                    r"\b{}\b".format(re.escape(rest[0])), src.read_text()):
                return
            break
    errors.append(f"{doc.relative_to(REPO)}: unresolvable symbol -> {ref}")


def main() -> int:
    errors: list = []
    for doc in DOC_FILES:
        if not doc.exists():
            errors.append(f"missing doc file: {doc.relative_to(REPO)}")
            continue
        text = doc.read_text()
        for m in LINK_RE.finditer(text):
            check_link(doc, m.group(1), errors)
        for m in PATH_RE.finditer(text):
            check_path_ref(doc, m.group(1), errors)
        for m in DOTTED_RE.finditer(text):
            check_dotted_ref(doc, m.group(1), errors)
    if errors:
        print("check_docs: FAILED")
        for e in errors:
            print("  " + e)
        return 1
    print(f"check_docs: OK ({len(DOC_FILES)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
