"""End-to-end serving driver: train a small LM briefly, then serve a batched
request stream through prefill + continuous-batching decode.

    PYTHONPATH=src python examples/serve_batched.py [--steps 30]
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import smoke_config
from repro.models import build_model
from repro.train import AdamWConfig, adamw_init, make_train_step
from repro.data import DataConfig, TokenPipeline
from repro.serve import ServeConfig, batched_serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config("phi3-mini-3.8b")
    model = build_model(cfg, remat=False)
    params = model.init_fn(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=5e-3)))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, global_batch=8,
                                    seq_len=64, seed=0))
    print(f"training {cfg.arch_id} (reduced) for {args.steps} steps ...")
    for i in range(args.steps):
        params, opt, m = step(params, opt, pipe.get_batch(i))
        if i % 10 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.3f}")

    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab, size=rng.integers(4, 20))
                for _ in range(args.requests)]
    print(f"\nserving {len(requests)} ragged requests in waves of 4 ...")
    t0 = time.perf_counter()
    outs = batched_serve(model, params, requests, batch_slots=4,
                         cfg=ServeConfig(max_new_tokens=8), prompt_len=20)
    dt = time.perf_counter() - t0
    tok_s = sum(len(o) for o in outs) / dt
    for i, o in enumerate(outs[:4]):
        print(f"  req {i}: prompt_len={len(requests[i]):2d} -> {o.tolist()}")
    print(f"throughput: {tok_s:.1f} tok/s (CPU, reduced model)")


if __name__ == "__main__":
    main()
