"""Deterministic sharded data pipeline.

Production shape: every (step, shard) microbatch is a pure function of the
(seed, step, shard) triple, so

  * any host can recompute any other host's microbatch (straggler
    mitigation / failure recovery need no data replay log);
  * restart-from-checkpoint resumes the exact token stream (fault-tolerance
    tests assert bit-identical loss trajectories).

Two sources: a synthetic LM stream (Zipf-ish unigram mix over the vocab —
enough structure for loss to fall), and a binary token-file reader with the
same deterministic step→offset mapping.  The cluster-balanced sampler is the
paper bridge: PR-Nibble clusters over a document graph re-weight document
sampling (examples/data_curation.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp

__all__ = ["DataConfig", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    num_shards: int = 1
    shard_id: int = 0
    token_file: Optional[str] = None
    # modality stubs
    enc_seq: int = 0           # whisper frames
    n_modality_tokens: int = 0
    d_model: int = 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards
        self._tokens = None
        if cfg.token_file:
            self._tokens = np.memmap(cfg.token_file, dtype=np.int32, mode="r")
        # Zipf-ish unigram distribution for the synthetic stream
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 97 + self.cfg.shard_id)

    def get_batch(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.local_batch, cfg.seq_len
        if self._tokens is not None:
            max_start = self._tokens.shape[0] - (s + 1)
            starts = rng.integers(0, max_start, size=b)
            seqs = np.stack([self._tokens[st: st + s + 1] for st in starts])
        else:
            # synthetic: unigram sample + short-range copy structure
            base = rng.choice(cfg.vocab, size=(b, s + 1), p=self._probs)
            copy_mask = rng.random((b, s + 1)) < 0.5
            shift = np.roll(base, 3, axis=1)
            seqs = np.where(copy_mask, shift, base).astype(np.int32)
        out = {"tokens": jnp.asarray(seqs[:, :-1]),
               "labels": jnp.asarray(seqs[:, 1:])}
        if cfg.enc_seq:
            out["frames"] = jnp.asarray(
                rng.standard_normal((b, cfg.enc_seq, cfg.d_model),
                                    dtype=np.float32))
        if cfg.n_modality_tokens:
            out["frontend_emb"] = jnp.asarray(
                rng.standard_normal((b, cfg.n_modality_tokens, cfg.d_model),
                                    dtype=np.float32))
            out["tokens"] = out["tokens"][:, : s - cfg.n_modality_tokens]
            out["labels"] = out["labels"][:, : s - cfg.n_modality_tokens]
        return out
