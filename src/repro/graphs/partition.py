"""Vertex partitioning for the distributed (multi-chip) diffusion engine.

Contiguous range partitioning: device ``i`` owns vertices
``[i*ceil(n/D), (i+1)*ceil(n/D))`` (the last shard is padded with isolated
sentinel vertices so every shard has identical static shape).  Ownership of a
vertex is therefore ``v // shard_size`` — computable on-device without a
lookup table, which is what the bucketed all_to_all router needs.

For graphs with locality (randLocal, grids, SBM with contiguous blocks) range
partitioning also minimizes boundary edges; for social graphs a reordering
(e.g. degree-sort or METIS-style) can be applied up front — ``reorder`` hooks
are provided but orthogonal to the exchange machinery.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from .csr import CSRGraph

__all__ = ["PartitionedCSR", "partition_rows", "degree_reorder"]


@dataclasses.dataclass(frozen=True)
class PartitionedCSR:
    """Row-sharded CSR: per-device slabs stacked on a leading device axis.

    ``indptr[d]`` is local (offsets into ``indices[d]``); column ids stay
    *global*.  All slabs are padded to identical shape so the whole structure
    can be fed through ``shard_map`` with a ``P('data')`` leading axis.

    Padding contract: rows ``[n_true, n)`` are *sentinel* vertices added so
    every shard has identical static shape.  They are guaranteed isolated —
    degree 0, no real edge targets them, and the ``indices`` pad value is the
    out-of-range sentinel ``n`` — so they can never enter a frontier (the
    drivers' ``deg > 0`` guard) nor a sweep cut (zero mass, zero degree);
    :func:`partition_rows` validates this and every consumer slices state
    vectors back to ``n_true``.
    """

    indptr: jnp.ndarray    # int32[D, rows_per+1]
    indices: jnp.ndarray   # int32[D, max_local_nnz]
    deg: jnp.ndarray       # int32[D, rows_per]
    n: int                 # global (padded) vertex count == rows_per · D
    m: int                 # global undirected edge count
    num_shards: int
    rows_per: int
    n_true: int = -1       # unpadded vertex count (-1: unknown, treat as n)

    def __post_init__(self):
        if self.n_true < 0:
            object.__setattr__(self, "n_true", self.n)
        if self.n_true < self.n:
            # degree-0 guard for *every* padded row, wherever it lives (the
            # padding can span shards when rows_per < num_padded) — validated
            # here so externally constructed instances honor the contract too
            deg = np.asarray(self.deg).reshape(-1)
            if deg[self.n_true:].any():
                raise ValueError(
                    "padded sentinel rows must have degree 0 — a nonzero-"
                    "degree pad vertex could enter a frontier or sweep cut")

    @property
    def num_padded(self) -> int:
        """Sentinel vertices appended to fill the last shard."""
        return self.n - self.n_true

    def owner(self, v):
        return v // self.rows_per

    def local_id(self, v):
        return v % self.rows_per


def partition_rows(graph: CSRGraph, num_shards: int) -> PartitionedCSR:
    g = graph.to_numpy()
    rows_per = -(-g.n // num_shards)  # ceil
    n_pad = rows_per * num_shards
    deg = np.zeros((num_shards, rows_per), dtype=np.int32)
    indptrs = np.zeros((num_shards, rows_per + 1), dtype=np.int32)
    slabs = []
    for d in range(num_shards):
        lo, hi = d * rows_per, min((d + 1) * rows_per, g.n)
        local_deg = np.zeros(rows_per, dtype=np.int32)
        if hi > lo:
            local_deg[: hi - lo] = g.deg[lo:hi]
        deg[d] = local_deg
        indptrs[d, 1:] = np.cumsum(local_deg)
        if hi > lo:
            slabs.append(g.indices[g.indptr[lo]: g.indptr[hi]])
        else:
            slabs.append(np.zeros(0, dtype=np.int32))
    max_nnz = max(1, max(s.shape[0] for s in slabs))
    # pad value is n_pad — one past the last (padded) vertex, so a stray read
    # of a pad slot can never alias a real vertex
    indices = np.full((num_shards, max_nnz), n_pad, dtype=np.int32)
    for d, s in enumerate(slabs):
        if s.size and int(s.max()) >= g.n:
            raise ValueError(
                f"shard {d} has an edge targeting vertex {int(s.max())} >= "
                f"n={g.n}: padded sentinel vertices must stay isolated")
        indices[d, : s.shape[0]] = s
    # (the degree-0 padding guard lives in PartitionedCSR.__post_init__, so
    # externally constructed instances are validated identically)
    return PartitionedCSR(
        indptr=jnp.asarray(indptrs),
        indices=jnp.asarray(indices),
        deg=jnp.asarray(deg),
        n=int(n_pad),
        m=g.m,
        num_shards=num_shards,
        rows_per=rows_per,
        n_true=int(g.n),
    )


def degree_reorder(graph: CSRGraph) -> tuple[CSRGraph, np.ndarray]:
    """Relabel vertices by decreasing degree (heavy rows first — balances
    range shards for power-law graphs).  Returns (new_graph, perm) where
    ``perm[old] = new``."""
    g = graph.to_numpy()
    order = np.argsort(-g.deg, kind="stable")
    perm = np.empty(g.n, dtype=np.int64)
    perm[order] = np.arange(g.n)
    from .csr import build_csr  # local import to avoid cycle at module load

    src = np.repeat(np.arange(g.n), g.deg)
    edges = np.stack([perm[src], perm[g.indices[: 2 * g.m]]], axis=1)
    return build_csr(edges, g.n), perm
