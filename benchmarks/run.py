"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --fast trims graph sizes (default);
--full runs the complete suite; --smoke runs each benchmark's smallest
config (the CI gate — must finish in a couple of minutes on one CPU core).

Every requested suite runs even if an earlier one fails; failures are
reported as ``<suite>/ERROR`` rows and the process exits nonzero at the end
(the CI gate must fail loudly, not skip silently).

Artifacts: EVERY suite writes a ``BENCH_<suite>.json`` next to the CWD,
containing the CSV rows it emitted (captured via ``common.emit``) plus —
when its ``run()`` returns a dict — that dict merged in (the serving
suite's latency summary, the dist suite's exchange-volume accounting).
CI uploads all of them, so the ops/batched/dist perf trajectories
accumulate across runs alongside the serving latencies.
"""
import argparse
import json
import sys
import time
import traceback

# Versioned artifact header (satellite of the tracing PR): accumulated
# BENCH_<suite>.json files must be comparable across PRs without guessing
# their vintage.  Bump when the artifact shape changes.
BENCH_SCHEMA = "repro.bench/v1"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="smallest config per benchmark; used by CI")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table3,fig2,fig6,fig9,fig10,"
                         "kernels,batched,sparse_batched,ops,serve,"
                         "dist_batched")
    args = ap.parse_args()
    from . import (table1_pushes, table3_runtimes, fig2_opt_rule, fig6_params,
                   fig9_sweep_scaling, fig10_ncp, kernels_bench, batched_bench,
                   sparse_batched_bench, ops_microbench, serve_bench,
                   dist_batched_bench)
    from .common import drain_rows
    smoke = args.smoke
    suites = {
        "table1": lambda: table1_pushes.run(smoke=smoke),
        "table3": lambda: table3_runtimes.run(fast=not args.full, smoke=smoke),
        "fig2": lambda: fig2_opt_rule.run(smoke=smoke),
        "fig6": lambda: fig6_params.run(smoke=smoke),
        "fig9": lambda: fig9_sweep_scaling.run(smoke=smoke),
        "fig10": lambda: fig10_ncp.run(smoke=smoke),
        "kernels": lambda: kernels_bench.run(smoke=smoke),
        "batched": lambda: batched_bench.run(smoke=smoke),
        "sparse_batched": lambda: sparse_batched_bench.run(smoke=smoke),
        "ops": lambda: ops_microbench.run(smoke=smoke),
        "serve": lambda: serve_bench.run(smoke=smoke),
        "dist_batched": lambda: dist_batched_bench.run(smoke=smoke),
    }
    only = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failures = []
    for k in only:
        drain_rows()   # rows are per-suite; discard anything stale
        try:
            ret = suites[k]()
        except Exception as e:
            print(f"{k}/ERROR,0,{type(e).__name__}:{str(e)[:120]}",
                  file=sys.stdout, flush=True)
            traceback.print_exc(file=sys.stderr)
            failures.append(k)
            drain_rows()
            continue
        artifact = dict(schema=BENCH_SCHEMA, suite=k, smoke=smoke,
                        generated_unix=time.time(), rows=drain_rows())
        if isinstance(ret, dict):
            artifact.update(ret)
        path = f"BENCH_{k}.json"
        with open(path, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
        print(f"wrote {path}", file=sys.stderr)
    if failures:
        print(f"FAILED suites: {','.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
