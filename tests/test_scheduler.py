"""Deadline-aware async serving (serve/scheduler.py + serve/telemetry.py).

The contracts under test, matching the acceptance criteria:

  * EDF: futures resolve out of submission order when deadlines demand it,
    and the planner provably orders the tight-deadline pool first.
  * Deadline expiry returns a best-effort partial flagged
    ``deadline_missed=True`` instead of blocking until convergence.
  * ``QueueFull`` at the admission bound.
  * Scheduling never changes answers: a scheduled stream's per-request
    results are bit-identical to ``LocalClusterEngine.run()`` on the same
    requests.
  * ``serve_forever()`` drives from a background thread; telemetry exports
    JSON the whole way.
"""
import json
import threading

import numpy as np
import pytest

from repro.core import pr_nibble
from repro.serve import (AsyncClusterEngine, ClusterFuture, ClusterRequest,
                         LocalClusterEngine, MetricsRegistry, QueueFull)
from repro.serve.telemetry import EMA, Histogram, pool_label

ENGINE_CAPS = dict(cap_f=1 << 11, cap_e=1 << 15, cap_n=1 << 10,
                   sweep_cap_e=1 << 15)


# ----------------------------------------------------------------- telemetry

def test_histogram_percentiles_and_summary():
    h = Histogram()
    for v in range(1, 101):          # 1..100 ms
        h.record(v / 1000.0)
    assert h.count == 100
    assert h.percentile(50) == pytest.approx(0.050, abs=0.002)
    assert h.percentile(99) == pytest.approx(0.099, abs=0.002)
    s = h.summary()
    assert s["count"] == 100 and s["p95"] >= s["p50"]


def test_ema_tracks_and_registry_roundtrips_json():
    e = EMA(alpha=0.5)
    assert e.value is None
    e.update(1.0)
    e.update(3.0)
    assert e.value == pytest.approx(2.0)
    reg = MetricsRegistry()
    reg.inc("a/count", 3)
    reg.set_gauge("a/depth", 7.0)
    reg.ema("a/cost").update(0.25)
    reg.observe("a/lat", 0.01)
    snap = json.loads(reg.to_json())
    assert snap["counters"]["a/count"] == 3
    assert snap["gauges"]["a/depth"] == 7.0
    assert snap["emas"]["a/cost"] == 0.25
    assert snap["histograms"]["a/lat"]["count"] == 1
    assert reg.ema_value("a/cost") == 0.25 and reg.ema_value("missing") is None


# ------------------------------------------------------------ EDF scheduling

def test_edf_futures_resolve_out_of_submission_order(sbm_graph):
    """A slow low-priority request is submitted first; a tight-deadline
    request (different pool) second.  Strict EDF (one pool per tick) must
    plan the deadlined pool first and resolve its future first."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               max_pools_per_tick=1, rounds_per_step=1,
                               **ENGINE_CAPS)
    # slow: small eps → many push rounds, 1 round per tick
    slow = sched.submit(ClusterRequest(seed=5, alpha=0.01, eps=1e-7,
                                       priority=0))
    fast = sched.submit(ClusterRequest(seed=305, method="hk_pr", eps=1e-4,
                                       N=5, t=5.0),
                        deadline_ms=60_000.0)
    order = []
    for _ in range(800):
        sched.tick()
        if fast.done() and "fast" not in order:
            order.append("fast")
        if slow.done() and "slow" not in order:
            order.append("slow")
        if fast.done() and not slow.done():
            # while both were live, the planner put the deadlined pool first
            assert sched.last_plan, "planner produced no order"
        if slow.done() and fast.done():
            break
    assert order == ["fast", "slow"], "EDF must finish the deadline first"
    assert not fast.result().deadline_missed
    assert not slow.result().deadline_missed


def test_edf_planner_orders_deadlined_pool_first(sbm_graph):
    """Direct planner assertion: with two live pools, the one holding the
    earlier deadline leads the plan; priority breaks undeadlined ties."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               max_pools_per_tick=0,  # plan only, step nothing
                               **ENGINE_CAPS)
    sched.submit(ClusterRequest(seed=5, alpha=0.01, eps=1e-7))
    tight = sched.submit(ClusterRequest(seed=305, method="hk_pr", eps=1e-4,
                                        N=5, t=5.0), deadline_ms=50.0)
    sched.tick()     # admits, then plans over both pools
    assert len(sched.last_plan) == 2
    assert sched.last_plan[0][0] == "hk_pr", \
        "tight-deadline pool must lead the EDF plan"
    # undeadlined priority ordering
    sched2 = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                                max_pools_per_tick=0, **ENGINE_CAPS)
    sched2.submit(ClusterRequest(seed=5, alpha=0.01, eps=1e-6), priority=0)
    sched2.submit(ClusterRequest(seed=305, method="hk_pr", eps=1e-4, N=5,
                                 t=5.0), priority=3)
    sched2.tick()
    assert sched2.last_plan[0][0] == "hk_pr", \
        "higher priority must lead among undeadlined pools"
    for s in (sched, sched2):     # re-enable stepping before draining
        s.max_pools_per_tick = None
        s.drain()


# ----------------------------------------------------------- deadline expiry

def test_deadline_expiry_harvests_partial_not_blocking(sbm_graph):
    """An already-expired deadline resolves on the next tick with a partial
    best-effort result flagged deadline_missed=True — it never blocks until
    convergence."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               rounds_per_step=1, **ENGINE_CAPS)
    fut = sched.submit(ClusterRequest(seed=11, alpha=0.01, eps=1e-7),
                       deadline_ms=0.0)
    sched.tick()
    assert fut.done(), "expired request must resolve immediately, not drain"
    res = fut.result()
    assert res.deadline_missed
    # one tick of stepping happened before expiry: partial mass was swept
    assert res.iterations >= 1 and res.support > 0
    # the partial is strictly less work than the converged run
    full = pr_nibble(sbm_graph, 11, 1e-7, 0.01,
                     cap_f=ENGINE_CAPS["cap_f"], cap_e=ENGINE_CAPS["cap_e"])
    assert res.pushes < int(full.pushes)
    assert sched.engine.stats["partial_harvests"] == 1
    assert sched.telemetry.counter_value("scheduler/deadline_missed") == 1


def test_deadline_expiry_of_queued_request_completes_empty(sbm_graph):
    """A request that expires while still waiting for a lane (never injected)
    completes with an empty partial, also flagged."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=1, max_queue=8,
                               rounds_per_step=1, **ENGINE_CAPS)
    occupant = sched.submit(ClusterRequest(seed=5, alpha=0.01, eps=1e-7))
    queued = sched.submit(ClusterRequest(seed=105, alpha=0.01, eps=1e-7),
                          deadline_ms=0.0)
    sched.tick()
    assert queued.done()
    res = queued.result()
    assert res.deadline_missed and res.size == 0 and res.support == 0
    assert res.pushes == 0 and res.cluster.shape == (0,)
    sched.drain()
    assert not occupant.result().deadline_missed


def test_late_natural_completion_is_flagged_not_silent(sbm_graph):
    """A request that finishes by itself after its deadline is delivered in
    full but flagged deadline_missed — never silently late."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               **ENGINE_CAPS)
    # generous work, impossible deadline: whichever path resolves it
    # (partial harvest or late completion) must carry the flag
    fut = sched.submit(ClusterRequest(seed=7, alpha=0.05, eps=1e-5),
                       deadline_ms=1e-6)
    sched.drain()
    assert fut.result().deadline_missed


# --------------------------------------------------------- admission control

def test_queue_full_at_admission_bound(sbm_graph):
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=2,
                               **ENGINE_CAPS)
    sched.submit(ClusterRequest(seed=1, alpha=0.05, eps=1e-5))
    sched.submit(ClusterRequest(seed=2, alpha=0.05, eps=1e-5))
    with pytest.raises(QueueFull, match="max_queue"):
        sched.submit(ClusterRequest(seed=3, alpha=0.05, eps=1e-5))
    assert sched.telemetry.counter_value("scheduler/rejected") == 1
    sched.drain()    # the bound frees as work resolves
    fut = sched.submit(ClusterRequest(seed=3, alpha=0.05, eps=1e-5))
    sched.drain()
    assert fut.done()


def test_submit_validates_on_caller_thread(sbm_graph):
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, **ENGINE_CAPS)
    with pytest.raises(ValueError, match="unknown method"):
        sched.submit(ClusterRequest(seed=1, method="nope"))
    assert sched.inflight() == 0, "rejected request must not hold a slot"


# ------------------------------------------------- scheduling never changes answers

def test_scheduled_results_bit_identical_to_run(sbm_graph):
    """Acceptance: per-request results of a scheduled stream equal
    LocalClusterEngine.run() on the same requests, field for field."""
    rng = np.random.default_rng(9)
    reqs = []
    for i in range(10):
        seed = int(rng.integers(0, sbm_graph.n))
        if i % 3 == 2:
            reqs.append(ClusterRequest(seed=seed, method="hk_pr", eps=1e-5,
                                       N=10, t=5.0))
        else:
            reqs.append(ClusterRequest(
                seed=seed, alpha=float(rng.choice([0.05, 0.01])),
                eps=float(rng.choice([1e-5, 1e-6]))))
    ref = LocalClusterEngine(sbm_graph, batch_slots=4, **ENGINE_CAPS)
    ref_results = ref.run(reqs)

    sched = AsyncClusterEngine(sbm_graph, batch_slots=4, max_queue=32,
                               max_pools_per_tick=1, **ENGINE_CAPS)
    futs = [sched.submit(r) for r in reqs]
    sched.drain()
    for fut, want in zip(futs, ref_results):
        got = fut.result()
        assert not got.deadline_missed
        assert got.conductance == want.conductance
        assert got.size == want.size
        assert got.volume == want.volume
        assert got.support == want.support
        assert got.pushes == want.pushes
        assert got.iterations == want.iterations
        assert got.bucket == want.bucket
        np.testing.assert_array_equal(got.cluster, want.cluster)


# -------------------------------------------------------- background thread

def test_serve_forever_background_thread_and_callbacks(sbm_graph):
    seen = []
    done_evt = threading.Event()

    def cb(fut: ClusterFuture):
        seen.append(fut.result().size)
        if len(seen) == 3:
            done_evt.set()

    with AsyncClusterEngine(sbm_graph, batch_slots=4, max_queue=32,
                            **ENGINE_CAPS) as sched:
        futs = [sched.submit(ClusterRequest(seed=s, alpha=0.05, eps=1e-5),
                             deadline_ms=60_000.0) for s in (5, 105, 205)]
        for f in futs:
            f.add_done_callback(cb)
        assert done_evt.wait(timeout=60.0), "callbacks never fired"
    assert sorted(seen) == sorted(f.result().size for f in futs)
    assert all(f.latency_ms is not None and f.latency_ms >= 0 for f in futs)
    # the registry saw the whole lifecycle and exports as JSON
    snap = json.loads(sched.telemetry.to_json())
    assert snap["counters"]["scheduler/submitted"] == 3
    assert snap["counters"]["scheduler/completed"] == 3
    assert any(k.startswith("pool/") and k.endswith("/tick_latency")
               for k in snap["histograms"])
    assert any(k.endswith("/tick_cost") for k in snap["emas"])
    assert "scheduler/inflight" in snap["gauges"]


def test_future_result_timeout():
    fut = ClusterFuture(ClusterRequest(seed=0))
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)


def test_wrapping_an_existing_engine(sbm_graph):
    eng = LocalClusterEngine(sbm_graph, batch_slots=2, **ENGINE_CAPS)
    sched = AsyncClusterEngine(eng, max_queue=4)
    assert sched.engine is eng
    # a ticket submitted to the shared engine out-of-band must survive the
    # scheduler's bulk pickup and stay claimable via engine.result()
    oob = eng.submit(ClusterRequest(seed=205, alpha=0.05, eps=1e-5))
    fut = sched.submit(ClusterRequest(seed=5, alpha=0.05, eps=1e-5))
    sched.drain()
    assert fut.result().size > 0
    eng.drain()                      # finish the out-of-band ticket if needed
    assert eng.result(oob).size > 0
    with pytest.raises(ValueError, match="engine_kwargs"):
        AsyncClusterEngine(eng, batch_slots=4)


# ------------------------------------------------------------ cost plumbing

def test_pool_cost_observables_feed_planner(sbm_graph):
    """tick_pool measures wall time into the pool EMA; pending_ticks is
    positive while work remains and the registry's EMA mirrors the pool's."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               rounds_per_step=1, **ENGINE_CAPS)
    sched.submit(ClusterRequest(seed=5, alpha=0.01, eps=1e-6))
    sched.tick()
    (key, pool), = sched.engine.live_pools()
    assert pool.cost_ema is not None and pool.cost_ema > 0
    assert pool.ticks >= 1
    assert pool.pending_ticks() >= 1
    assert pool.occupancy() >= 1
    reg_ema = sched.telemetry.ema_value(f"pool/{pool_label(key)}/tick_cost")
    assert reg_ema is not None and reg_ema > 0
    sched.drain()
    assert pool.pending_ticks() == 0


# ------------------------------------------------------- result-cache wiring

def test_cache_hit_resolves_before_admission_under_deadline(sbm_graph):
    """A repeat request resolves at submit() straight from the engine's
    seed→result cache: done before any tick, bit-identical to the computed
    twin, never flagged late — even under a deadline no lane could meet —
    and without occupying a lane or a queue slot."""
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               **ENGINE_CAPS)
    first = sched.submit(ClusterRequest(seed=11, alpha=0.05, eps=1e-4))
    sched.drain()
    a = first.result()
    injections = sched.engine.stats["injections"]
    fut = sched.submit(ClusterRequest(seed=11, alpha=0.05, eps=1e-4),
                       deadline_ms=1e-3)
    assert fut.done()                    # resolved at submit: zero ticks ran
    b = fut.result()
    assert not b.deadline_missed
    assert sched.engine.stats["injections"] == injections
    assert sched.inflight() == 0
    assert sched.telemetry.counter_value("scheduler/cache_hits") == 1
    assert a.conductance == b.conductance and a.size == b.size
    assert a.pushes == b.pushes and a.iterations == b.iterations
    assert np.array_equal(a.cluster, b.cluster)


def test_cost_table_seeds_planner_cold_start(sbm_graph, tmp_path):
    """The characterized tick-cost table keys the EDF planner's estimate for
    a never-ticked pool: exact pool label first, then the method:backend
    family fallback — the cold-start fix for freshly created pools."""
    from repro.serve.telemetry import load_cost_table, lookup_cost
    p = tmp_path / "tick_costs.json"
    p.write_text(json.dumps(dict(schema="repro.bench.tick_costs/v1",
                                 entries={"pr_nibble:dense": 0.123})))
    sched = AsyncClusterEngine(sbm_graph, batch_slots=2, max_queue=8,
                               cost_table=str(p), **ENGINE_CAPS)
    assert sched.cost_table == {"pr_nibble:dense": 0.123}
    # enqueue straight at the engine: the pool now exists but never ticked
    t = sched.engine.submit(ClusterRequest(seed=5, alpha=0.05, eps=1e-4))
    (key, pool), = sched.engine.live_pools()
    assert pool.cost_ema is None         # the cold-start case
    assert lookup_cost(sched.cost_table, key) == 0.123
    sched.engine.drain()
    assert sched.engine.result(t).size > 0
    # unreadable/malformed tables degrade to the built-in guess, never raise
    assert load_cost_table(str(tmp_path / "missing.json")) == {}
