"""Quickstart: find a local cluster around a seed vertex in 20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.graphs import sbm
from repro.core import pr_nibble, sweep_cut_dense

# a graph with 8 planted communities of 100 vertices each
graph = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
seed_vertex = 5  # lives in community 0 (vertices 0..99)

# parallel PR-Nibble (optimized update rule) + Theorem-1 sweep cut
diff = pr_nibble(graph, seed_vertex, eps=1e-7, alpha=0.01)
sweep = sweep_cut_dense(graph, diff.p, cap_n=1 << 11, cap_e=1 << 17)

members = np.sort(np.asarray(sweep.cluster())[: int(sweep.best_size)])
print(f"seed vertex          : {seed_vertex}")
print(f"diffusion pushes     : {int(diff.pushes)} over "
      f"{int(diff.iterations)} parallel rounds")
print(f"cluster size         : {int(sweep.best_size)}")
print(f"cluster conductance  : {float(sweep.best_conductance):.4f}")
print(f"members in community : {np.mean(members < 100) * 100:.1f}%")
print(f"first members        : {members[:12].tolist()} ...")
