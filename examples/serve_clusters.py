"""Local clustering as a service: many-seed throughput demo.

A burst of mixed-parameter clustering queries (random seeds, α, ε, and a mix
of PR-Nibble and HK-PR) is served four ways:

  1. naive loop — one single-seed jit call per query (the seed repo's path)
  2. batched    — one ``batched_pr_nibble`` dispatch for the PR-Nibble burst
  3. engine     — ``LocalClusterEngine`` continuous batching: fixed lanes,
                  finished slots refilled without recompiling, per-request
                  sweep cuts, overflow promoted through capacity buckets
  4. async      — ``AsyncClusterEngine`` deadline-aware serving: requests
                  submitted with latency budgets from the caller's thread
                  while the scheduler drives in the background (EDF pool
                  ordering), results consumed via future callbacks, and the
                  telemetry registry dumped as JSON at exit

    PYTHONPATH=src python examples/serve_clusters.py [--requests 48]
"""
import argparse
import threading
import time

import numpy as np

from repro.core import pr_nibble, hk_pr, sweep_cut_dense, batched_pr_nibble
from repro.graphs import rand_local
from repro.serve import AsyncClusterEngine, ClusterRequest, LocalClusterEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--batch-slots", type=int, default=16)
    ap.add_argument("--eps", type=float, default=1e-4,
                    help="base truncation threshold (smaller = less local)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "dense", "sparse"],
                    help="engine lane type; sparse = O(cap_v) state per lane "
                         "(HK-PR requests always serve dense)")
    args = ap.parse_args()

    print(f"building randLocal graph (n={args.n}) ...")
    g = rand_local(args.n, degree=5, seed=0)
    rng = np.random.default_rng(1)
    seeds = rng.choice(np.flatnonzero(np.asarray(g.deg) > 0),
                       size=args.requests).astype(np.int32)
    reqs = []
    for i, s in enumerate(seeds):
        if i % 4 == 3:
            reqs.append(ClusterRequest(seed=int(s), method="hk_pr",
                                       eps=args.eps, N=10, t=5.0))
        else:
            reqs.append(ClusterRequest(
                seed=int(s), alpha=float(rng.choice([0.1, 0.05])),
                eps=float(rng.choice([args.eps, args.eps / 3]))))

    # 1. naive loop (with per-request sweep, same work as the engine)
    t0 = time.perf_counter()
    naive = []
    for q in reqs:
        if q.method == "pr_nibble":
            res = pr_nibble(g, q.seed, q.eps, q.alpha)
        else:
            res = hk_pr(g, q.seed, N=q.N, eps=q.eps, t=q.t)
        naive.append(sweep_cut_dense(g, res.p, 1 << 11, 1 << 17))
    dt_loop = time.perf_counter() - t0
    print(f"naive loop      : {len(reqs) / dt_loop:7.1f} seeds/s "
          f"({dt_loop * 1e3:.0f} ms total)")

    # 2. one batched dispatch for the PR-Nibble subset (diffusion only)
    prn = [q for q in reqs if q.method == "pr_nibble"]
    t0 = time.perf_counter()
    out = batched_pr_nibble(g, np.asarray([q.seed for q in prn], np.int32),
                            np.asarray([q.eps for q in prn], np.float32),
                            np.asarray([q.alpha for q in prn], np.float32))
    dt_bat = time.perf_counter() - t0
    print(f"batched dispatch: {len(prn) / dt_bat:7.1f} seeds/s "
          f"({len(out.buckets)} capacity bucket(s), PR-Nibble subset)")

    # 3. the serving engine: mixed methods, slot refill, sweep included
    eng = LocalClusterEngine(g, batch_slots=args.batch_slots,
                             backend=args.backend)
    t0 = time.perf_counter()
    results = eng.run(reqs)
    dt_eng = time.perf_counter() - t0
    print(f"cluster engine  : {len(reqs) / dt_eng:7.1f} seeds/s "
          f"({dt_eng * 1e3:.0f} ms total, incl. sweep cuts)")
    s = eng.stats
    print(f"  steps={s['steps']} injections={s['injections']} "
          f"promotions={s['promotions']} pools={s['pools_created']} "
          f"compiled_shapes={len(s['bucket_shapes'])}")

    best = min(results, key=lambda r: r.conductance)
    print(f"\nbest cluster: seed={best.request.seed} size={best.size} "
          f"phi={best.conductance:.4f} ({best.request.method})")
    for r in results[:4]:
        print(f"  seed={r.request.seed:6d} {r.request.method:9s} "
              f"eps={r.request.eps:g} size={r.size:4d} "
              f"phi={r.conductance:.4f} pushes={r.pushes}")

    # 4. deadline-aware async serving: submit with budgets from this thread,
    #    the scheduler ticks in its own; consume via callbacks
    print("\nasync serving (deadline-aware):")
    done = threading.Event()
    hits, misses = [], []

    def on_done(fut):
        r = fut.result()
        (misses if r.deadline_missed else hits).append(fut)
        if len(hits) + len(misses) == len(reqs):
            done.set()

    with AsyncClusterEngine(g, batch_slots=args.batch_slots,
                            max_queue=4 * len(reqs),
                            backend=args.backend) as sched:
        t0 = time.perf_counter()
        for i, q in enumerate(reqs):
            # tight budgets on every 3rd request show the miss path;
            # the rest get a comfortable budget
            fut = sched.submit(q, deadline_ms=25.0 if i % 3 == 0 else 5000.0,
                               priority=1 if i % 3 == 0 else 0)
            fut.add_done_callback(on_done)
        done.wait(timeout=120.0)
        dt = time.perf_counter() - t0
        print(f"async engine    : {len(reqs) / dt:7.1f} seeds/s "
              f"({dt * 1e3:.0f} ms wall, submit-to-callback)")
        lat = sorted(f.latency_ms for f in hits + misses)
        print(f"  p50={lat[len(lat) // 2]:.1f}ms "
              f"p95={lat[int(0.95 * (len(lat) - 1))]:.1f}ms  "
              f"deadline hits={len(hits)} misses={len(misses)} "
              f"(misses return flagged partial harvests, never block)")
        telemetry_json = sched.telemetry.to_json()
    print("telemetry dump (truncated):")
    for line in telemetry_json.splitlines()[:16]:
        print("  " + line)
    print(f"  ... ({len(telemetry_json.splitlines())} lines total)")


if __name__ == "__main__":
    main()
