"""Train a ~100M-parameter LM for a few hundred steps — the end-to-end
training driver (scaled for real hardware; on this CPU container use
--tiny for a fast demonstration of the same path).

    PYTHONPATH=src python examples/train_lm.py --tiny          # CPU demo
    PYTHONPATH=src python examples/train_lm.py --steps 300     # ~100M run
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.train import (AdamWConfig, Checkpointer, adamw_init,
                         make_train_step)
from repro.data import DataConfig, TokenPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # a ~100M-param yi-family config (12L, d=768, 12H, tied 32k vocab)
    base = get_config("yi-6b")
    cfg = dataclasses.replace(
        base, arch_id="yi-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
        q_chunk=256, kv_chunk=256,
        param_dtype="float32", compute_dtype="float32")
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                                  n_kv_heads=2, head_dim=32, d_ff=512,
                                  vocab=2048)
        args.steps = min(args.steps, 60)
        args.seq = min(args.seq, 128)

    model = build_model(cfg, remat=True)
    params = model.init_fn(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.arch_id}: {n / 1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    ocfg = AdamWConfig(lr=6e-4, warmup_steps=args.steps // 20 + 1,
                       total_steps=args.steps)
    step = jax.jit(make_train_step(model, ocfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, global_batch=args.batch,
                                    seq_len=args.seq, seed=0))
    opt = adamw_init(params)
    ck = Checkpointer(args.ckpt_dir, keep=2)
    t0 = time.perf_counter()
    for i in range(args.steps):
        params, opt, m = step(params, opt, pipe.get_batch(i))
        if i % 20 == 0 or i == args.steps - 1:
            tok_s = args.batch * args.seq * (i + 1) / (time.perf_counter() - t0)
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f} ({tok_s:,.0f} tok/s)")
        if i and i % 100 == 0:
            ck.save({"params": params, "opt": opt}, i)
    ck.save({"params": params, "opt": opt}, args.steps - 1, blocking=True)
    ck.close()
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
