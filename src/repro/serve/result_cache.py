"""Versioned seed→result LRU cache for the serving engine.

At serving scale, hot seeds repeat: the same community query arrives from
many users, and a converged diffusion is a pure function of
``(graph, method, seed, α, ε, statics)``.  This module memoizes those
results so a repeated query returns in O(1) *before admission* — no lane,
no tick, no sweep.

Key design (:func:`result_key`):

  * ``graph_version`` leads the key — callers bump
    :attr:`repro.graphs.handle.GraphHandle.version` when the graph's
    edges change, which makes every cached community stale at once (old
    versions age out of the LRU; no scan-and-purge).
  * The *kernel* backend (ops_backend) is excluded: results are
    bit-identical across it (docs/algorithms.md, guarantee #6), so an xla
    hit may serve a pallas request and vice versa.
  * The *lane* backend is folded to its bit-identity class: dense and dist
    lanes produce bit-identical rows (guarantee #7) and share entries;
    sparse lanes run the sparse update order and key separately
    (guarantee #5 ties them to the *sparse* single-seed driver, not to the
    dense one) — a cached answer must be the exact bits the lane would
    have computed.

Only converged results enter the cache: deadline-missed partials are
best-effort snapshots of an interrupted diffusion, not values of the pure
function.  A hit returns a *copy* whose ``request`` field is the incoming
request (deadlines/priority differ between hits), so callers may mutate
their result without corrupting the cache (guarantee #9: caching never
changes answers).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Optional

__all__ = ["ResultCache", "result_key"]


def result_key(req, lane_backend: str, graph_version: int = 0) -> tuple:
    """Cache key for one request: ``(graph_version, method, seed, α, ε,
    statics, lane-identity-class)``.  ``lane_backend`` is the *resolved*
    lane type ("dense" | "sparse" | "dist" — never "auto"); dense and dist
    collapse to one class (bit-identical rows, guarantee #7)."""
    if req.method == "pr_nibble":
        statics = (req.optimized, req.beta)
    else:
        statics = (req.N, req.t)
    family = "sparse" if lane_backend == "sparse" else "dense"
    return (graph_version, req.method, int(req.seed), float(req.alpha),
            float(req.eps), statics, family)


class ResultCache:
    """Bounded, thread-safe LRU of :class:`ClusterResult` by result key.

    ``get`` counts hits/misses (the engine's ``result_cache_hits`` /
    ``result_cache_misses`` stats and the scheduler's MetricsRegistry
    counters read them); ``put`` refuses deadline-missed partials.  The
    LRU bound is entries, not bytes — a community is O(|cluster|), small by
    the locality of the algorithms being served.
    """

    def __init__(self, capacity: int = 1024) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: tuple, request=None):
        """The cached :class:`ClusterResult` for ``key`` (marked
        most-recently-used), or None.  The returned result is a fresh copy
        carrying ``request`` (when given) so hit consumers can't alias the
        cached arrays; ``deadline_missed`` is always False on a hit — the
        cached value is the converged answer, delivered instantly."""
        with self._lock:
            res = self._entries.get(key)
            if res is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
        return dataclasses.replace(
            res, request=(request if request is not None else res.request),
            cluster=res.cluster.copy(), deadline_missed=False)

    def put(self, key: tuple, result) -> bool:
        """Insert a *converged* result (partials are rejected — a
        deadline-missed harvest is not the pure function's value).  Returns
        True if stored."""
        if result.deadline_missed:
            return False
        with self._lock:
            self._entries[key] = result
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        return True

    def invalidate(self) -> None:
        """Drop every entry (graph-version bumps make this unnecessary for
        graph mutations; exposed for tests and manual resets)."""
        with self._lock:
            self._entries.clear()

    def stats(self) -> dict:
        with self._lock:
            return dict(entries=len(self._entries), capacity=self.capacity,
                        hits=self.hits, misses=self.misses,
                        evictions=self.evictions)
