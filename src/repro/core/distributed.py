"""Distributed local graph clustering — the paper's engine at pod scale.

The paper targets one shared-memory node.  At 10⁹+-vertex scale the state
vectors and the graph no longer fit one chip, so this module lifts the
frontier-synchronous push to a *vertex-partitioned* SPMD program under
``shard_map``:

  * vertices are range-partitioned: device d owns rows
    [d·rows_per, (d+1)·rows_per)  (graphs/partition.py);
  * ``p``/``r`` live sharded (each device holds its slice);
  * each round, every device expands its *local* frontier from its CSR slab,
    producing (global dst, value) contributions;
  * contributions are routed to their owners with a **bucketed all_to_all**:
    sort by owner, slice per-owner buckets of static capacity, exchange,
    local scatter-add — message volume ∝ boundary mass, the distributed
    analogue of the paper's work-locality;
  * termination is a replicated carried scalar (psum of frontier sizes), so
    every device runs the same number of rounds — frontier-synchronous, like
    the paper's rounds, with the ICI all_to_all replacing the shared memory.

The same machinery drives distributed PR-Nibble here and is reused by the
multi-pod dry-run configs (launch/dryrun.py `graph_*` cells).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.graphs.partition import PartitionedCSR
from . import ops
from .frontier import scatter_add_dense, scatter_set_dense

__all__ = ["DistPRNibbleResult", "dist_pr_nibble",
           "build_dist_pr_nibble", "local_frontier_pack",
           "push_shares", "owner_buckets"]


class DistPRNibbleResult(NamedTuple):
    p: jnp.ndarray           # f32[n_true]  (padded sentinel rows sliced off)
    r: jnp.ndarray           # f32[n_true]
    iterations: jnp.ndarray  # int32 (replicated)
    pushes: jnp.ndarray      # int32 global pushes
    overflow: jnp.ndarray    # bool
    exchanged: jnp.ndarray = None  # int32 — cross-shard contribution slots
    #   routed over all rounds (the exchange volume the boundary-mass
    #   locality argument bounds; see benchmarks/dist_batched_bench.py).
    #   None only if constructed by legacy callers that predate the field.


class _Shard(NamedTuple):
    p: jnp.ndarray           # f32[rows_per] local slice
    r: jnp.ndarray
    t: jnp.ndarray           # replicated scalars
    pushes: jnp.ndarray
    global_front: jnp.ndarray
    overflow: jnp.ndarray
    exchanged: jnp.ndarray   # replicated int32 — cross-shard routed slots


def _local_expand(indptr, indices, deg, f_loc, f_valid, cap_e, rows_per,
                  backend="xla"):
    """Expand a local frontier (local ids) against the local CSR slab.
    Returns (slot, dst_global, evalid, total)."""
    degs = jnp.where(f_valid, deg[jnp.minimum(f_loc, rows_per - 1)], 0)
    offs = ops.prefix_sum(degs, backend=backend) - degs
    total = offs[-1] + degs[-1]
    j = jnp.arange(cap_e, dtype=jnp.int32)
    slot = jnp.searchsorted(offs, j, side="right").astype(jnp.int32) - 1
    slot = jnp.clip(slot, 0, f_loc.shape[0] - 1)
    within = j - offs[slot]
    evalid = j < total
    row = jnp.minimum(f_loc[slot], rows_per - 1)
    base = indptr[row]
    eidx = jnp.clip(base + within, 0, indices.shape[0] - 1)
    dst = jnp.where(evalid & f_valid[slot], indices[eidx], jnp.int32(2**30))
    return slot, dst, evalid & f_valid[slot], total


# Shared round primitives — these encode the *fold-order-critical* pieces of
# the bit-identity guarantee (docs/algorithms.md #7), so they exist exactly
# once and both distributed engines (this single-seed one and the batched
# core/batched_dist.py) call them.

_GLOBAL_SENTINEL = 2 ** 30   # "nowhere" destination for masked slots


def local_frontier_pack(r_loc, deg, eps, rows_per: int, cap_f: int,
                        backend: str = "xla"):
    """Pack local ids with ``r >= d*eps`` (deg > 0) ascending into ``cap_f``
    slots.  Ascending local order is load-bearing: concatenated device-major
    it reproduces the single-chip sorted frontier.  Returns (ids, cnt) with
    ``cnt`` the *unclamped* above-threshold count (callers clamp/flag)."""
    above = (r_loc >= deg * eps) & (deg > 0)
    cnt = jnp.sum(above).astype(jnp.int32)
    pos = ops.prefix_sum(above.astype(jnp.int32), backend=backend) - 1
    ids = scatter_set_dense(
        jnp.full((cap_f,), rows_per, jnp.int32), pos,
        jnp.arange(rows_per, dtype=jnp.int32), above)
    return ids, cnt


def push_shares(rf, dv, alpha, optimized: bool):
    """The Fig 3 / Fig 4 push-rule arithmetic: (p_gain, r_self, share) for
    frontier residuals ``rf`` over degrees ``dv`` — identical expressions to
    :func:`repro.core.pr_nibble.pr_nibble_round`, which the bit-identity of
    every distributed driver depends on."""
    if optimized:                      # Fig 4 (optimal step size)
        return ((2.0 * alpha / (1.0 + alpha)) * rf,
                jnp.zeros_like(rf),
                ((1.0 - alpha) / (1.0 + alpha)) * rf / dv)
    return (alpha * rf,                # Fig 3
            (1.0 - alpha) * rf / 2.0,
            (1.0 - alpha) * rf / (2.0 * dv))


def owner_buckets(dst, contrib, evalid, D: int, rows_per: int, cap_x: int,
                  cap_e: int):
    """Route (dst, contrib) slots into per-owner buckets [D, cap_x] for the
    all_to_all.  The argsort is *stable*, preserving each owner's slots in
    expansion-stream order — with the source-major concatenation on the
    receive side this reproduces the single-chip scatter fold order.
    Returns (owner, send_dst, send_val, x_ovf)."""
    owner = jnp.where(evalid, dst // rows_per, D)   # D = invalid
    order = jnp.argsort(owner)                      # stable
    owner_s = owner[order]
    dst_s = dst[order]
    val_s = contrib[order]
    rng_d = jnp.arange(D, dtype=jnp.int32)
    start = jnp.searchsorted(owner_s, rng_d, side="left")
    count = (jnp.searchsorted(owner_s, rng_d, side="right")
             - start).astype(jnp.int32)
    x_ovf = jnp.any(count > cap_x)
    gidx = start[:, None] + jnp.arange(cap_x, dtype=jnp.int32)[None, :]
    in_bucket = jnp.arange(cap_x, dtype=jnp.int32)[None, :] < count[:, None]
    gsafe = jnp.clip(gidx, 0, cap_e - 1)
    send_dst = jnp.where(in_bucket, dst_s[gsafe], jnp.int32(_GLOBAL_SENTINEL))
    send_val = jnp.where(in_bucket, val_s[gsafe], 0.0)
    return owner, send_dst, send_val, x_ovf



def build_dist_pr_nibble(mesh, axis: str = "data", exchange: str = "a2a",
                         backend: str = "xla"):
    """Build the shard_map'd distributed PR-Nibble for a given mesh axis.

    ``exchange`` selects the contribution-routing collective:
      * "a2a"  — bucketed all_to_all (message volume ∝ boundary mass; the
                 locality-preserving scheme, default);
      * "psum" — naive baseline: scatter into a full dense [n] buffer and
                 all-reduce it (O(n) bytes per round regardless of frontier
                 size — what the roofline comparison in §Perf quantifies).

    ``backend`` routes every per-device scatter-add/scan through
    :mod:`repro.core.ops` (the same op layer the single-chip drivers use —
    the distributed engine is local pushes + a collective, nothing more).

    Returns fn(pg_arrays..., x, eps, alpha) -> DistPRNibbleResult, jit-able
    with in_shardings placing the partition slabs and state on `axis`.
    """
    D = mesh.shape[axis]

    def engine(indptr, indices, deg, x, eps, alpha, *, rows_per: int,
               cap_f: int, cap_e: int, cap_x: int, max_iters: int):
        """Runs INSIDE shard_map: args are per-device slabs.
        indptr: int32[1, rows_per+1]; indices: int32[1, nnz]; deg: int32[1, rows_per]
        x: int32 replicated seed; returns sharded p, r + replicated stats."""
        indptr = indptr[0]
        indices = indices[0]
        deg = deg[0]
        me = jax.lax.axis_index(axis)
        base = me * rows_per

        def cond(s: _Shard):
            return (s.global_front > 0) & (~s.overflow) & (s.t < max_iters)

        def body(s: _Shard) -> _Shard:
            f_loc, cnt = local_frontier_pack(s.r, deg, eps, rows_per, cap_f,
                                             backend)
            f_cnt = jnp.minimum(cnt, cap_f)
            f_ovf = cnt > cap_f
            f_valid = jnp.arange(cap_f, dtype=jnp.int32) < f_cnt
            safe = jnp.minimum(f_loc, rows_per - 1)
            rf = jnp.where(f_valid, s.r[safe], 0.0)
            dv = jnp.maximum(deg[safe], 1)

            p_gain, r_self, share = push_shares(rf, dv, alpha, True)

            p_new = scatter_add_dense(s.p, f_loc, p_gain, f_valid,
                                      backend=backend)
            r_new = scatter_set_dense(s.r, f_loc, r_self, f_valid)

            slot, dst, evalid, etot = _local_expand(
                indptr, indices, deg, f_loc, f_valid, cap_e, rows_per,
                backend)
            e_ovf = etot > cap_e   # silently-truncated expansion must retry
            contrib = jnp.where(evalid, share[slot], 0.0)

            if exchange == "psum":
                # naive baseline: dense global buffer + all-reduce
                dense = scatter_add_dense(
                    jnp.zeros((rows_per * D,), jnp.float32), dst, contrib,
                    evalid, backend=backend)
                dense = jax.lax.psum(dense, axis)
                mine_slice = jax.lax.dynamic_slice_in_dim(
                    dense, base, rows_per, 0)
                r_new = r_new + mine_slice
                x_ovf = jnp.asarray(False)
                exch = jnp.asarray(0, jnp.int32)
            else:
                # ---- bucketed all_to_all routing ----
                owner, send_dst, send_val, x_ovf = owner_buckets(
                    dst, contrib, evalid, D, rows_per, cap_x, cap_e)
                recv_dst = jax.lax.all_to_all(send_dst, axis, 0, 0, tiled=True)
                recv_val = jax.lax.all_to_all(send_val, axis, 0, 0, tiled=True)
                # local scatter-add: global → local ids
                loc = recv_dst.reshape(-1) - base
                ok = (loc >= 0) & (loc < rows_per)
                r_new = scatter_add_dense(r_new, loc, recv_val.reshape(-1),
                                          ok, backend=backend)
                exch = jnp.sum((owner != me) & evalid).astype(jnp.int32)

            # replicated termination stats
            nxt_above = jnp.sum((r_new >= deg * eps) & (deg > 0))
            gfront = jax.lax.psum(nxt_above, axis)
            gpush = jax.lax.psum(f_cnt, axis)
            gexch = jax.lax.psum(exch, axis)
            ovf = jax.lax.psum((f_ovf | x_ovf | e_ovf).astype(jnp.int32),
                               axis) > 0
            return _Shard(p=p_new, r=r_new, t=s.t + 1,
                          pushes=s.pushes + gpush,
                          global_front=gfront.astype(jnp.int32),
                          overflow=s.overflow | ovf,
                          exchanged=s.exchanged + gexch)

        # init: seed owner puts mass 1 (drop-sentinel masked — the non-owner
        # previously relied on adding 0.0 at a clipped in-range index)
        r0 = jnp.zeros((rows_per,), jnp.float32)
        mine = (x >= base) & (x < base + rows_per)
        r0 = scatter_add_dense(r0, jnp.clip(x - base, 0, rows_per - 1),
                               jnp.float32(1.0), mine)
        s0 = _Shard(p=jnp.zeros((rows_per,), jnp.float32), r=r0,
                    t=jnp.asarray(0, jnp.int32),
                    pushes=jnp.asarray(0, jnp.int32),
                    global_front=jnp.asarray(1, jnp.int32),
                    overflow=jnp.asarray(False),
                    exchanged=jnp.asarray(0, jnp.int32))
        s = jax.lax.while_loop(cond, body, s0)
        return s.p, s.r, s.t, s.pushes, s.overflow, s.exchanged

    def make(rows_per: int, cap_f: int, cap_e: int, cap_x: int,
             max_iters: int = 10_000):
        eng = functools.partial(engine, rows_per=rows_per, cap_f=cap_f,
                                cap_e=cap_e, cap_x=cap_x, max_iters=max_iters)
        smapped = shard_map(
            eng, mesh=mesh,
            in_specs=(P(axis), P(axis), P(axis), P(), P(), P()),
            out_specs=(P(axis), P(axis), P(), P(), P(), P()))
        return smapped

    return make


def dist_pr_nibble(graph, mesh=None, x: int = 0, eps: float = 1e-7,
                   alpha: float = 0.01, axis: str = "data",
                   cap_f: int = 1 << 12, cap_e: int = 1 << 16,
                   cap_x: int = 1 << 12, max_cap_e: int = 1 << 24,
                   backend: str = "xla") -> DistPRNibbleResult:
    """Driver: distributed PR-Nibble (optimized rule) with bucket retry.

    ``graph`` is any graph-like (`repro.graphs.handle.as_handle`):
    a ``PartitionedCSR`` (then ``mesh`` is required), a ``CSRGraph`` to
    shard over ``mesh``, or a sharded ``GraphHandle`` carrying its own mesh.
    The returned ``p``/``r`` are sliced to the true vertex count — the
    partition's sentinel padding never escapes this driver.
    """
    from repro.graphs.handle import as_handle
    handle = as_handle(graph, mesh=mesh, axis=axis)
    mesh = handle.require_mesh()
    axis = handle.axis
    pg = handle.partitioned()
    make = build_dist_pr_nibble(mesh, axis, backend=backend)
    n_true = pg.n_true
    while True:
        fn = jax.jit(make(pg.rows_per, cap_f, cap_e, cap_x))
        p, r, t, pushes, ovf, exch = fn(
            pg.indptr, pg.indices, pg.deg,
            jnp.asarray(x, jnp.int32), jnp.float32(eps), jnp.float32(alpha))
        if not bool(ovf) or cap_e >= max_cap_e:
            return DistPRNibbleResult(p=p.reshape(-1)[:n_true],
                                      r=r.reshape(-1)[:n_true],
                                      iterations=t, pushes=pushes,
                                      overflow=ovf, exchanged=exch)
        cap_f = min(cap_f * 2, pg.rows_per + 1)
        cap_e *= 2
        cap_x = min(cap_x * 2, cap_e)
