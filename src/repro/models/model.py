"""Unified model API: config → {init, loss, prefill, decode, specs}.

Every assigned architecture exposes the same four entry points so that
train/serve/launch code is arch-agnostic:

  * ``init_fn(key)``                      → params pytree
  * ``loss_fn(params, batch)``            → scalar loss      (train_* cells)
  * ``prefill_fn(params, batch)``         → (cache, logits)  (prefill_* cells)
  * ``decode_fn(params, token, cache)``   → (logits, cache)  (decode_* cells)

plus shape/spec helpers used by the dry-run launcher (everything below works
on ``jax.eval_shape`` of these functions — no allocation at scale).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from . import lm as _lm
from . import encdec as _encdec
from .sharding import param_specs, cache_specs, batch_axes

__all__ = ["Model", "build_model", "make_batch_specs"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_fn: Callable
    loss_fn: Callable
    prefill_fn: Callable
    decode_fn: Callable

    def abstract_params(self, key=None):
        key = jax.random.PRNGKey(0) if key is None else key
        return jax.eval_shape(self.init_fn, key)

    def abstract_cache(self, batch: int, max_seq: int):
        if self.cfg.enc_dec:
            def mk():
                c = _lm.init_cache(self.cfg, batch, max_seq)
                c["enc_out"] = jnp.zeros(
                    (batch, self.cfg.enc_seq, self.cfg.d_model),
                    jnp.dtype(self.cfg.compute_dtype))
                return c
            return jax.eval_shape(mk)
        return jax.eval_shape(lambda: _lm.init_cache(self.cfg, batch, max_seq))

    def param_partition_specs(self, mesh=None):
        return param_specs(self.abstract_params(), mesh)

    def cache_partition_specs(self, batch: int, max_seq: int, mesh):
        bspec = batch_axes(batch, mesh)
        return cache_specs(self.abstract_cache(batch, max_seq), bspec, mesh)


def build_model(cfg: ModelConfig, remat: bool = True) -> Model:
    if cfg.enc_dec:
        def init_fn(key):
            return _encdec.encdec_init(key, cfg)

        def loss_fn(params, batch):
            return _encdec.encdec_loss(params, batch, cfg, remat=remat)

        def prefill_fn(params, batch, max_seq=None):
            max_seq = max_seq or batch["tokens"].shape[1] + 64
            cache, logits, enc_out = _encdec.encdec_prefill(
                params, batch["frames"], batch["tokens"], cfg,
                max_seq=max_seq, remat=remat)
            cache["enc_out"] = enc_out
            return cache, logits

        def decode_fn(params, token, cache):
            enc_out = cache["enc_out"]
            core = {k: v for k, v in cache.items() if k != "enc_out"}
            logits, new_core = _encdec.encdec_decode_step(
                params, token, core, enc_out, cfg)
            new_core["enc_out"] = enc_out
            return logits, new_core
    else:
        def init_fn(key):
            return _lm.lm_init(key, cfg)

        def loss_fn(params, batch):
            return _lm.lm_loss(params, batch, cfg, remat=remat)

        def prefill_fn(params, batch, max_seq=None):
            # headroom for decode writes beyond the prompt
            max_seq = max_seq or batch["tokens"].shape[1] + 64
            return _lm.lm_prefill(params, batch["tokens"], cfg,
                                  max_seq=max_seq, remat=remat)

        def decode_fn(params, token, cache):
            return _lm.lm_decode_step(params, token, cache, cfg)

    return Model(cfg=cfg, init_fn=init_fn, loss_fn=loss_fn,
                 prefill_fn=prefill_fn, decode_fn=decode_fn)


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                     dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell
    (the dry-run's input_specs)."""
    b, s = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.enc_dec:
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                                 cdt)
        if cfg.n_modality_tokens:
            # frontend stub supplies patch/frame embeddings; text tokens
            # shrink so total sequence stays at the assigned seq_len
            m = cfg.n_modality_tokens
            out["tokens"] = jax.ShapeDtypeStruct((b, s - m), jnp.int32)
            out["labels"] = jax.ShapeDtypeStruct((b, s - m), jnp.int32)
            out["frontend_emb"] = jax.ShapeDtypeStruct((b, m, cfg.d_model), cdt)
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.enc_dec:
            out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model),
                                                 cdt)
        if cfg.n_modality_tokens:
            m = cfg.n_modality_tokens
            out["tokens"] = jax.ShapeDtypeStruct((b, s - m), jnp.int32)
            out["frontend_emb"] = jax.ShapeDtypeStruct((b, m, cfg.d_model), cdt)
        return out
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    raise ValueError(shape.kind)
