"""Attention: GQA with RoPE, flash-style chunked causal/full/local variants,
and single-token decode against a KV cache.

GQA is implemented by **expanding K/V to the full head count** before the
score einsums (``jnp.repeat`` along the head axis).  Under GSPMD this is the
clean tensor-parallel form: Q/K/V all end up sharded on the same `model`
head axis, every einsum contracts unsharded dims, and no resharding copies
appear (the grouped-query form `[B,S,Kv,G,Dh]` forces the partitioner into
"involuntary full rematerialization" when H is model-sharded).  When the KV
head count doesn't divide the axis, K/V projections stay replicated and the
repeat slices locally.

Memory discipline is what makes the 32k-prefill and 500k-decode cells
lowerable: scores are never materialized beyond a (q_chunk × kv_chunk) tile:

  * ``flash_causal``  — two-level ``lax.scan`` (query chunks × kv chunks)
    with online-softmax carry (m, l, acc);
  * ``local_causal``  — query-chunk scan; each chunk attends to a
    ``dynamic_slice`` window of the KV (compute ∝ S·window, not S²);
  * ``full_bidir``    — encoder attention (whisper);
  * ``decode_attend`` — one token vs. the cache: a [B,H,1,S] score row.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, rope

__all__ = ["attn_init", "attn_project_qkv", "attn_output", "expand_kv",
           "flash_causal", "local_causal", "full_bidir", "decode_attend",
           "mha", "pick_chunk"]

_NEG = -1e30


def attn_init(key, d_model, n_heads, n_kv_heads, head_dim, dtype="bfloat16"):
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model,), (n_heads, head_dim), dtype),
        "wk": dense_init(kk, (d_model,), (n_kv_heads, head_dim), dtype),
        "wv": dense_init(kv, (d_model,), (n_kv_heads, head_dim), dtype),
        "wo": dense_init(ko, (n_heads, head_dim), (d_model,), dtype),
    }


def attn_project_qkv(params, x, positions, rope_theta: Optional[float]):
    q = dense(params["wq"], x, "bsd,dhq->bshq")
    k = dense(params["wk"], x, "bsd,dhq->bshq")
    v = dense(params["wv"], x, "bsd,dhq->bshq")
    if rope_theta is not None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


def attn_output(params, o):
    return dense(params["wo"], o, "bshq,hqd->bsd")


def expand_kv(kv, n_heads: int):
    """[B,S,Kv,Dh] → [B,S,H,Dh] by repeating each KV head H/Kv times."""
    n_kv = kv.shape[2]
    if n_kv == n_heads:
        return kv
    return jnp.repeat(kv, n_heads // n_kv, axis=2)


def pick_chunk(s: int, pref: int) -> int:
    """Largest divisor of s that is ≤ pref (shape-safe chunking)."""
    c = min(pref, s)
    while s % c != 0:
        c -= 1
    return c


def flash_causal(q, k, v, q_chunk: int = 512, kv_chunk: int = 1024):
    """Causal flash attention via two-level scan.  q,k,v: [B,S,H,Dh]
    (k/v already expanded to H heads)."""
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    nq = s // q_chunk
    nk = s // kv_chunk
    qs = (q * scale).reshape(b, nq, q_chunk, h, dh)

    def q_step(_, qi):
        qc, iq = qi                                     # qc [b,qch,h,dh]

        def kv_step(carry, ik):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, ik * kv_chunk, kv_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, ik * kv_chunk, kv_chunk, 1)
            sc = jnp.einsum("bqhd,bshd->bhqs", qc, ks,
                            preferred_element_type=jnp.float32)
            qpos = iq * q_chunk + jnp.arange(q_chunk)
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            mask = qpos[:, None] >= kpos[None, :]
            sc = jnp.where(mask[None, None], sc, _NEG)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqs,bshd->bhqd", p.astype(vs.dtype), vs
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, q_chunk), _NEG, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, h, q_chunk, dh), jnp.float32)
        # scanning all nk chunks keeps shapes static; chunks fully in the
        # causal-masked future contribute exp(−inf)=0.  The step body is
        # checkpointed: backward recomputes the score tile instead of
        # saving [B,H,qch,kch] residuals per (q,kv) pair — the flash
        # backward-recompute discipline, expressed with jax.checkpoint.
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # [b,h,qch,dh]
        # cast inside the scan so the stacked ys are bf16, not f32
        return None, out.transpose(0, 2, 1, 3).astype(q.dtype)

    _, chunks = jax.lax.scan(
        jax.checkpoint(q_step), None,
        (qs.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def local_causal(q, k, v, window: int, q_chunk: int = 512):
    """Sliding-window causal attention: each query chunk attends to a
    dynamic-sliced KV window of width (window + q_chunk)."""
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    if s <= window + q_chunk or s % q_chunk != 0:
        return flash_causal(q, k, v, pick_chunk(s, q_chunk),
                            pick_chunk(s, max(window, q_chunk)))
    span = window + q_chunk                             # static window span
    qs = (q * scale).reshape(b, s // q_chunk, q_chunk, h, dh)

    def q_step(_, qi):
        qc, iq = qi
        start = jnp.maximum(iq * q_chunk + q_chunk - span, 0)
        ks = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
        sc = jnp.einsum("bqhd,bshd->bhqs", qc, ks,
                        preferred_element_type=jnp.float32)
        qpos = iq * q_chunk + jnp.arange(q_chunk)
        kpos = start + jnp.arange(span)
        mask = (qpos[:, None] >= kpos[None, :]) & \
               (qpos[:, None] - kpos[None, :] < window)
        sc = jnp.where(mask[None, None], sc, _NEG)
        p = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bhqs,bshd->bqhd", p.astype(vs.dtype), vs)
        return None, out.astype(q.dtype)

    _, chunks = jax.lax.scan(
        jax.checkpoint(q_step), None,
        (qs.transpose(1, 0, 2, 3, 4), jnp.arange(s // q_chunk)))
    out = chunks.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def full_bidir(q, k, v, kv_chunk: int = 1024):
    """Bidirectional attention (whisper encoder / decoder cross-attn)."""
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    sc = jnp.einsum("bqhd,bshd->bhqs", q * scale, k,
                    preferred_element_type=jnp.float32)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)


def decode_attend(q, k_cache, v_cache, length, window: Optional[int] = None):
    """One-token attention against the cache.

    q: [B,1,H,Dh]; k/v_cache: [B,S,H,Dh] (expanded); length = cache fill.
    """
    b, _, h, dh = q.shape
    s = k_cache.shape[1]
    scale = dh ** -0.5
    sc = jnp.einsum("bqhd,bshd->bhqs", q * scale, k_cache,
                    preferred_element_type=jnp.float32)
    pos = jnp.arange(s)
    ok = pos < length
    if window is not None:
        ok = ok & (pos >= length - window)
    sc = jnp.where(ok[None, None, None, :], sc, _NEG)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", p.astype(v_cache.dtype), v_cache)
    return out.astype(q.dtype)


def mha(params, x, positions, kind: str, cfg, enc_out=None):
    """Full attention sub-layer (projections + core + output)."""
    q, k, v = attn_project_qkv(params, x, positions, cfg.rope_theta)
    k = expand_kv(k, cfg.n_heads)
    v = expand_kv(v, cfg.n_heads)
    s = x.shape[1]
    if kind == "attn_local":
        o = local_causal(q, k, v, cfg.window, pick_chunk(s, cfg.q_chunk))
    elif kind in ("attn", "attn_global"):
        o = flash_causal(q, k, v, pick_chunk(s, cfg.q_chunk),
                         pick_chunk(s, cfg.kv_chunk))
    elif kind == "attn_bidir":
        o = full_bidir(q, k, v, cfg.kv_chunk)
    else:
        raise ValueError(kind)
    return attn_output(params, o)
