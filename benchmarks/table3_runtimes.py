"""Table 3 analogue: runtimes of the four diffusions + sweep cut,
JAX engine vs sequential numpy reference, across the graph suite.

Paper params (Table 3 caption): Nibble T=20 ε=1e-8; PR-Nibble α=0.01 ε=1e-7;
HK-PR t=10 N=20 ε=1e-7; rand-HK-PR t=10 K=10 (N scaled down for CPU).
On this CPU the JAX engine's vectorized rounds play the "parallel" role; the
real scaling story is the roofline dry-run.
"""
import numpy as np
import jax

from repro.core import (nibble, pr_nibble, hk_pr, rand_hk_pr,
                        sweep_cut_dense, seq)
from .common import GRAPH_SUITE, get_graph, emit, timeit


def run(fast: bool = True, smoke: bool = False):
    if smoke:
        graphs, walks = ["sbm-planted"], 1024
    else:
        graphs = ["sbm-planted", "3D-grid-20"] if fast else list(GRAPH_SUITE)
        walks = 4096 if fast else 1 << 16
    for name in graphs:
        g = get_graph(name)
        seed = 5 if name == "sbm-planted" else int(np.argmax(np.asarray(g.deg)))

        us, nres = timeit(nibble, g, seed, 1e-8, 20, repeats=1)
        emit(f"table3/{name}/nibble_par", us, f"pushes={int(nres.pushes)}")
        us, _ = timeit(lambda: seq.seq_nibble(g, seed, 1e-8, 20), repeats=1)
        emit(f"table3/{name}/nibble_seq", us, "")

        us, pres = timeit(pr_nibble, g, seed, 1e-7, 0.01, repeats=1)
        emit(f"table3/{name}/pr_nibble_par", us,
             f"pushes={int(pres.pushes)};iters={int(pres.iterations)}")
        us, _ = timeit(lambda: seq.seq_pr_nibble(g, seed, 1e-7, 0.01),
                       repeats=1)
        emit(f"table3/{name}/pr_nibble_seq", us, "")

        us, hres = timeit(hk_pr, g, seed, 20, 1e-7, 10.0, repeats=1)
        emit(f"table3/{name}/hk_pr_par", us, f"pushes={int(hres.pushes)}")
        us, _ = timeit(lambda: seq.seq_hk_pr(g, seed, 20, 1e-7, 10.0),
                       repeats=1)
        emit(f"table3/{name}/hk_pr_seq", us, "")

        us, rres = timeit(rand_hk_pr, g, seed, walks, 10, 10.0,
                          jax.random.PRNGKey(0), repeats=1)
        emit(f"table3/{name}/rand_hk_par", us, f"nnz={int(rres.nnz)}")
        us, _ = timeit(lambda: seq.seq_rand_hk_pr(g, seed, walks // 8, 10,
                                                  10.0), repeats=1)
        emit(f"table3/{name}/rand_hk_seq", us, f"walks={walks // 8}")

        # sweep on the Nibble output (paper's Table 3 convention)
        us, sres = timeit(sweep_cut_dense, g, nres.p, 1 << 12, 1 << 18,
                          repeats=1)
        emit(f"table3/{name}/sweep_par", us,
             f"cond={float(sres.best_conductance):.4f};size={int(sres.best_size)}")
        p_np = np.asarray(nres.p)
        p_dict = {i: float(p_np[i]) for i in np.flatnonzero(p_np > 0)}
        us, _ = timeit(lambda: seq.seq_sweep_cut(g, p_dict), repeats=1)
        emit(f"table3/{name}/sweep_seq", us, "")


if __name__ == "__main__":
    run()
