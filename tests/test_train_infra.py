"""Training substrate: loss falls, checkpoint/restart is exact, resharding,
int8 gradient path, data determinism, heartbeat."""
import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.models import build_model
from repro.train import (AdamWConfig, Checkpointer, OptState, adamw_init,
                         latest_step, load_pytree, make_train_step,
                         save_pytree, Heartbeat, quantize_grads_int8,
                         zero_shard_specs)
from repro.data import DataConfig, TokenPipeline


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("yi-6b")
    m = build_model(cfg, remat=True)
    params = m.init_fn(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=1e-2, warmup_steps=3,
                                                  total_steps=40)))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, global_batch=4,
                                    seq_len=64, seed=0))
    return m, params, opt, step, pipe


def test_loss_decreases(setup):
    m, params, opt, step, pipe = setup
    losses = []
    for i in range(10):
        params, opt, metrics = step(params, opt, pipe.get_batch(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_checkpoint_restart_bit_exact(setup):
    m, params, opt, step, pipe = setup
    with tempfile.TemporaryDirectory() as d:
        losses = []
        for i in range(6):
            params, opt, metrics = step(params, opt, pipe.get_batch(i))
            losses.append(float(metrics["loss"]))
            if i == 2:
                save_pytree({"params": params, "opt": opt}, d, i)
        restored, st = load_pytree({"params": params, "opt": opt}, d)
        p2 = jax.tree.map(jnp.asarray, restored["params"])
        o2 = jax.tree.map(jnp.asarray, restored["opt"])
        o2 = OptState(mu=o2.mu, nu=o2.nu, count=o2.count)
        replay = []
        for i in range(st + 1, 6):
            p2, o2, metrics = step(p2, o2, pipe.get_batch(i))
            replay.append(float(metrics["loss"]))
        assert replay == losses[st + 1:]   # EXACT, not approx


def test_checkpoint_commit_protocol(tmp_path, setup):
    m, params, opt, _, _ = setup
    d = str(tmp_path)
    save_pytree({"p": params}, d, 5)
    save_pytree({"p": params}, d, 9)
    assert latest_step(d) == 9
    # a torn write (no COMMITTED marker) must be ignored
    os.makedirs(os.path.join(d, "step_00000012"))
    assert latest_step(d) == 9


def test_async_checkpointer(tmp_path, setup):
    m, params, opt, _, _ = setup
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save({"p": params}, s)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    # retention: only last 2 kept
    kept = [f for f in os.listdir(str(tmp_path)) if f.endswith(".COMMITTED")]
    assert len(kept) == 2
    ck.close()


def test_int8_grad_quantization_roundtrip():
    g = {"a": jnp.asarray(np.random.default_rng(0).standard_normal(100),
                          jnp.float32)}
    q, scales = quantize_grads_int8(g)
    deq = jax.tree.map(lambda qi, s: qi.astype(jnp.float32) * s, q, scales)
    err = np.abs(np.asarray(deq["a"]) - np.asarray(g["a"])).max()
    assert err <= float(scales["a"]) * 0.51     # half-ulp of the quantizer


def test_data_pipeline_determinism():
    kw = dict(vocab=100, global_batch=4, seq_len=32, seed=7)
    a = TokenPipeline(DataConfig(**kw)).get_batch(13)
    b = TokenPipeline(DataConfig(**kw)).get_batch(13)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = TokenPipeline(DataConfig(**kw)).get_batch(14)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_shards_differ():
    base = dict(vocab=100, global_batch=8, seq_len=32, seed=7, num_shards=2)
    a = TokenPipeline(DataConfig(**base, shard_id=0)).get_batch(0)
    b = TokenPipeline(DataConfig(**base, shard_id=1)).get_batch(0)
    assert a["tokens"].shape == (4, 32)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_heartbeat_detects_death(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0, timeout=60)
    hb1 = Heartbeat(str(tmp_path), 1, timeout=60)
    hb0.beat()
    assert hb0.alive_hosts(2) == [0]
    assert hb0.dead_hosts(2) == [1]
    hb1.beat()
    assert hb0.dead_hosts(2) == []


def test_zero_shard_specs_divisibility(setup):
    m, params, _, _, _ = setup

    class FakeMesh:
        shape = {"data": 4}
    shapes = jax.eval_shape(lambda p: p, params)
    pspecs = m.param_partition_specs()
    zspecs = zero_shard_specs(pspecs, shapes, FakeMesh(), "data")
    for spec, shp in zip(jax.tree.leaves(zspecs), jax.tree.leaves(shapes)):
        for d, ax in enumerate(spec):
            if ax == "data":
                assert shp.shape[d] % 4 == 0
