from .engine import ServeConfig, generate, batched_serve
from .cluster_engine import ClusterRequest, ClusterResult, LocalClusterEngine

__all__ = ["ServeConfig", "generate", "batched_serve",
           "ClusterRequest", "ClusterResult", "LocalClusterEngine"]
