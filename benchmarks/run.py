"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  --fast trims graph sizes (default);
--full runs the complete suite.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table3,fig2,fig6,fig9,fig10,kernels")
    args = ap.parse_args()
    from . import (table1_pushes, table3_runtimes, fig2_opt_rule, fig6_params,
                   fig9_sweep_scaling, fig10_ncp, kernels_bench)
    suites = {
        "table1": lambda: table1_pushes.run(),
        "table3": lambda: table3_runtimes.run(fast=not args.full),
        "fig2": lambda: fig2_opt_rule.run(),
        "fig6": lambda: fig6_params.run(),
        "fig9": lambda: fig9_sweep_scaling.run(),
        "fig10": lambda: fig10_ncp.run(),
        "kernels": lambda: kernels_bench.run(),
    }
    only = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    for k in only:
        try:
            suites[k]()
        except Exception as e:  # keep the harness going; report the failure
            print(f"{k}/ERROR,0,{type(e).__name__}:{str(e)[:120]}",
                  file=sys.stdout, flush=True)
            raise


if __name__ == '__main__':
    main()
