"""Sort-merge sparse vectors — the TPU-native replacement for the paper's
concurrent hash table (§3 "Sparse Sets").

The paper stores (vertex → value) in a lock-free linear-probing hash table;
its complexity analysis only needs batched insert/lookup in O(N) work and
O(log N) depth.  On a TPU random probing is hostile, but *sort* is a native
primitive — so a sparse set here is a sorted, sentinel-padded
``(ids, vals)`` pair:

  * lookup  — ``searchsorted`` (O(log cap) per query, vectorized)
  * merge-add — concatenate + sort + adjacent-segment-sum + compaction
    (O((cap+U) log) work, O(log) depth for U updates — the same bounds as a
    batch of hash inserts, and deterministic)

Capacity is static per jit bucket; exceeding it raises the overflow flag and
the driver retries one bucket up (see frontier.py).

The merge-add reduction itself (sort → sum-duplicates → compact) is an op:
it dispatches through :func:`repro.core.ops.segment_merge`, so ``backend=
"pallas"`` fuses the post-sort pipeline into the MXU segment-merge kernel
(kernels/segment_merge.py) with bit-identical results to the XLA reference.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import ops
from .frontier import scatter_set_dense

__all__ = ["SparseVec", "sv_empty", "sv_lookup", "sv_merge_add",
           "sv_update_existing", "sv_from_pairs"]


class SparseVec(NamedTuple):
    ids: jnp.ndarray       # int32[cap] — sorted; sentinel (n) padded
    vals: jnp.ndarray      # f32[cap]
    count: jnp.ndarray     # int32
    overflow: jnp.ndarray  # bool

    @property
    def cap(self) -> int:
        return self.ids.shape[0]

    def valid(self) -> jnp.ndarray:
        return jnp.arange(self.cap, dtype=jnp.int32) < self.count


def sv_empty(cap: int, n: int) -> SparseVec:
    return SparseVec(ids=jnp.full((cap,), n, jnp.int32),
                     vals=jnp.zeros((cap,), jnp.float32),
                     count=jnp.asarray(0, jnp.int32),
                     overflow=jnp.asarray(False))


def sv_from_pairs(ids, vals, valid, cap: int, n: int,
                  backend: str = "xla") -> SparseVec:
    """Build from (possibly duplicated / unsorted) pairs: duplicates summed."""
    return sv_merge_add(sv_empty(cap, n), ids, vals, valid, n,
                        backend=backend)


def sv_lookup(sv: SparseVec, queries: jnp.ndarray, n: int) -> jnp.ndarray:
    """vals for each query id; 0.0 where absent (the paper's ⊥ = 0)."""
    pos = jnp.searchsorted(sv.ids, queries)
    pos = jnp.clip(pos, 0, sv.cap - 1)
    hit = (sv.ids[pos] == queries) & (queries < n)
    return jnp.where(hit, sv.vals[pos], 0.0)


def sv_update_existing(sv: SparseVec, ids, new_vals, valid) -> SparseVec:
    """Overwrite values of keys already present (no structural change)."""
    pos = jnp.clip(jnp.searchsorted(sv.ids, ids), 0, sv.cap - 1)
    hit = valid & (sv.ids[pos] == ids)
    return sv._replace(vals=scatter_set_dense(sv.vals, pos, new_vals, hit))


def sv_merge_add(sv: SparseVec, upd_ids, upd_vals, upd_valid, n: int,
                 backend: str = "xla") -> SparseVec:
    """`r[w] += delta` for a batch of updates — the fetchAdd batch.

    Concatenate the live entries with the updates, then one
    :func:`repro.core.ops.segment_merge`: sort by id, sum adjacent duplicates,
    compact back to `cap`.
    """
    cap = sv.cap
    ids_all = jnp.concatenate([
        jnp.where(sv.valid(), sv.ids, n),
        jnp.where(upd_valid, upd_ids, n).astype(jnp.int32)])
    vals_all = jnp.concatenate([
        jnp.where(sv.valid(), sv.vals, 0.0),
        jnp.where(upd_valid, upd_vals, 0.0)])
    out_ids, out_vals, new_count = ops.segment_merge(ids_all, vals_all, n,
                                                     cap, backend=backend)
    return SparseVec(ids=out_ids, vals=out_vals,
                     count=jnp.minimum(new_count, cap),
                     overflow=sv.overflow | (new_count > cap))
