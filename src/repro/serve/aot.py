"""Ahead-of-time compiled tick executables for the serving engine.

The steady-state serving tick must never trace: first-touch jit tracing is
tens-to-hundreds of milliseconds — longer than a typical deadline — and the
jit call path re-checks its cache on every dispatch.  This module lowers
each lane pool's tick kernels (:class:`repro.core.batched.LaneKernels`) to
XLA executables *once*, at pool creation (or eagerly, via
``LocalClusterEngine.warmup``), and caches them per pool key:

  * ``jax.jit(...).lower(...).compile()`` against the pool's exact avals —
    the compiled objects dispatch without re-entering the jit cache and keep
    their ``donate_argnums`` (lane state updates in place);
  * the cache key is the engine's pool key ``(method, backend, statics,
    ops_backend, bucket, topo)``, so a bucket-ladder promotion hops between
    already-compiled executables and an LRU-evicted pool's re-creation is a
    cache hit, never a re-trace;
  * ``compiles`` / ``hits`` / ``compile_seconds`` counters feed the engine's
    ``stats`` dict (and the re-trace-freedom guard in
    tests/test_serve_perf.py).

AOT compilation changes *when* programs are built, never what they compute:
the lowered jaxprs are the same ones the jit path would trace, so results
stay bit-identical (docs/algorithms.md, guarantee #9).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.batched import LaneKernels

__all__ = ["PoolExecutables", "ExecutableCache", "compile_lane_executables"]


class PoolExecutables(NamedTuple):
    """AOT-compiled tick entry points for one pool shape.  Same signatures
    as :class:`~repro.core.batched.LaneKernels` (init / inject / step /
    status / sweep), but each is a ``jax`` ``Compiled`` object: calling it
    never traces, and the donated state argument of ``inject``/``step`` is
    consumed (the caller must drop its reference, which the engine does by
    reassigning ``pool.state``)."""
    init: Callable
    inject: Callable
    step: Callable
    status: Callable
    sweep: Callable


def compile_lane_executables(kern: LaneKernels, graph,
                             batch_slots: int) -> PoolExecutables:
    """Lower + compile every kernel of ``kern`` against the pool's avals.

    ``graph`` is the concrete :class:`~repro.graphs.csr.CSRGraph` the pool
    serves (its arrays contribute avals only — the executables still take
    the graph as a runtime argument, so they are shared by construction
    with the jit path's trace).  The lane-state aval comes from
    ``eval_shape`` of the init kernel, so dense/sparse/HK pools all lower
    through this one function.
    """
    B = batch_slots
    seeds = jax.ShapeDtypeStruct((B,), jnp.int32)
    state = jax.eval_shape(kern.init, seeds)
    f32B = jax.ShapeDtypeStruct((B,), jnp.float32)
    boolB = jax.ShapeDtypeStruct((B,), jnp.bool_)
    i32 = jax.ShapeDtypeStruct((), jnp.int32)
    return PoolExecutables(
        init=kern.init.lower(seeds).compile(),
        inject=kern.inject.lower(state, i32, i32).compile(),
        step=kern.step.lower(graph, state, f32B, f32B, boolB).compile(),
        status=kern.status.lower(state).compile(),
        sweep=kern.sweep.lower(graph, state, i32).compile(),
    )


class ExecutableCache:
    """Pool-key → :class:`PoolExecutables` cache with compile accounting.

    One instance per engine (the executables close over that engine's graph
    avals and batch width).  ``get`` is locked — the async scheduler's
    drive thread and a caller running ``warmup`` may race pool creation —
    and builds at most once per key.  Evicting a *pool* (device state)
    never evicts its *executables*: compiled programs are small, bounded by
    the O(log) distinct bucket shapes a request stream can produce, and
    keeping them is exactly what makes pool re-creation re-trace-free.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: Dict[tuple, PoolExecutables] = {}
        self.compiles = 0          # cache misses: full lower+compile builds
        self.hits = 0              # cache hits: reused executable bundles
        self.compile_seconds = 0.0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: tuple,
            build: Callable[[], PoolExecutables]) -> PoolExecutables:
        """The executables for ``key``, building (and timing) on first use."""
        with self._lock:
            ex = self._entries.get(key)
            if ex is not None:
                self.hits += 1
                return ex
            t0 = time.perf_counter()
            ex = build()
            self.compile_seconds += time.perf_counter() - t0
            self.compiles += 1
            self._entries[key] = ex
            return ex

    def peek(self, key: tuple) -> Optional[PoolExecutables]:
        with self._lock:
            return self._entries.get(key)

    def stats(self) -> Dict:
        with self._lock:
            return dict(entries=len(self._entries), compiles=self.compiles,
                        hits=self.hits,
                        compile_seconds=self.compile_seconds)
