from .optimizer import (AdamWConfig, OptState, adamw_init, adamw_update,
                        warmup_cosine, clip_by_global_norm, zero_shard_specs,
                        quantize_grads_int8)
from .train_step import make_train_step, init_train_state, jit_train_step
from .checkpoint import Checkpointer, save_pytree, load_pytree, latest_step
from .elastic import reshard_state, Heartbeat

__all__ = [
    "AdamWConfig", "OptState", "adamw_init", "adamw_update", "warmup_cosine",
    "clip_by_global_norm", "zero_shard_specs", "quantize_grads_int8",
    "make_train_step", "init_train_state", "jit_train_step",
    "Checkpointer", "save_pytree", "load_pytree", "latest_step",
    "reshard_state", "Heartbeat",
]
