"""Work-efficient parallel sweep cut (paper §4.1, Theorem 1).

Given a diffusion vector ``p`` with N non-zeros, sort vertices by
``p[v]/d(v)`` descending, and over all prefixes S_j compute
``φ(S_j) = ∂(S_j) / min(vol(S_j), 2m − vol(S_j))``; return the argmin prefix.

The paper materializes ±1 pairs and integer-sorts them by rank.  We use the
mathematically identical *difference-array* formulation, which replaces the
integer sort with a scatter-add + prefix-sum (same O(vol(S_N)) work,
O(log vol) depth, and a better fit for XLA):

  for each directed edge (v, w) with rank(v) < rank(w):
      diff[rank(v)+1] += 1 ;  diff[min(rank(w), N)+1] -= 1
  ∂(S_j) = inclusive_prefix_sum(diff)[j]

Exactly one of the two directed copies of every undirected edge satisfies
rank(v) < rank(w) (case (a) in the paper; case (b) pairs are the zero
contribution), and an edge leaving S_N gets rank(w) = N so it crosses every
prefix that contains v.  vol(S_j) is the prefix sum of sorted degrees, and the
final min is a prefix-min — all three of the paper's §3 primitives, nothing
else.

Work: O(N log N + vol(S_N));  depth: O(log vol(S_N))  (Theorem 1).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from . import ops
from .frontier import Frontier, expand, scatter_set_dense

__all__ = ["SweepResult", "sweep_cut", "sweep_cut_dense", "sweep_cut_sparse"]

_INF = jnp.float32(jnp.inf)


def _boundary_cut(r_src, r_dst, go, cap_n: int, backend: str) -> jnp.ndarray:
    """∂(S_j) for every prefix j via the difference array (module docstring):
    +1 at rank(v)+1, −1 at rank(w)+1 for each crossing edge, then an
    inclusive prefix sum.  Shared by the dense and sparse sweeps; both
    scatters and the scan dispatch through :mod:`repro.core.ops` (int32 —
    exact on every backend)."""
    ones = jnp.ones(r_src.shape, jnp.int32)
    diff = jnp.zeros((cap_n + 2,), dtype=jnp.int32)
    diff = ops.scatter_add(diff, r_src + 1, ones, go, backend=backend)
    diff = ops.scatter_add(diff, r_dst + 1, -ones, go, backend=backend)
    return ops.prefix_sum(diff, backend=backend)[1: cap_n + 1]


class SweepResult(NamedTuple):
    best_conductance: jnp.ndarray  # f32 scalar
    best_size: jnp.ndarray         # int32 scalar — |S*| (prefix length)
    best_volume: jnp.ndarray       # int32 scalar — vol(S*)
    order: jnp.ndarray             # int32[cap_n] — vertex ids sorted by p/d
    conductance: jnp.ndarray       # f32[cap_n] — φ(S_j) per prefix (inf pad)
    volume: jnp.ndarray            # int32[cap_n] — vol(S_j) per prefix
    cut: jnp.ndarray               # int32[cap_n] — ∂(S_j) per prefix
    nnz: jnp.ndarray               # int32 scalar — N
    overflow: jnp.ndarray          # bool — edge workspace too small

    def cluster(self) -> jnp.ndarray:
        """Member ids of the best prefix, sentinel-padded."""
        keep = jnp.arange(self.order.shape[0]) < self.best_size
        return jnp.where(keep, self.order, jnp.iinfo(jnp.int32).max)


@functools.partial(jax.jit, static_argnums=(4,),
                   static_argnames=("cap_e", "backend"))
def sweep_cut(graph: CSRGraph, ids: jnp.ndarray, vals: jnp.ndarray,
              nnz: jnp.ndarray, cap_e: int, *,
              backend: str = "xla") -> SweepResult:
    """Sweep over a sparse diffusion vector.

    Args:
      graph: CSR graph (a registered pytree: array leaves are traced, the
        static (n, m) aux data keys the jit cache).
      ids:  int32[cap_n] vertex ids (sentinel ``n`` beyond ``nnz``)
      vals: f32[cap_n]   diffusion mass for each id
      nnz:  int32 scalar — number of valid (id, val) pairs
      cap_e: static edge-workspace capacity (≥ vol(S_N))
      backend: kernel backend for the scatters/scans (repro.core.ops)
    """
    n, m = graph.n, graph.m
    cap_n = ids.shape[0]
    arange_n = jnp.arange(cap_n, dtype=jnp.int32)
    valid = arange_n < nnz
    ids = jnp.where(valid, ids, n).astype(jnp.int32)

    deg = graph.deg[jnp.minimum(ids, n - 1)]
    deg = jnp.where(ids < n, deg, 0)
    # sort by p/d descending; invalid slots sink to the end
    q = jnp.where(valid & (deg > 0), vals / jnp.maximum(deg, 1), -_INF)
    perm = jnp.argsort(-q)
    order = ids[perm]
    valid_s = valid[perm] & (deg[perm] > 0)
    deg_s = jnp.where(valid_s, deg[perm], 0)
    nnz_eff = jnp.sum(valid_s).astype(jnp.int32)

    # rank table (the paper's `rank` sparse set → dense O(n) table; the
    # *work* to build it is O(N))
    rank = jnp.full((n + 1,), cap_n, dtype=jnp.int32)
    rank = scatter_set_dense(rank, order, arange_n, valid_s)

    # expand all edges of S_N (degree prefix-sum + searchsorted)
    front = Frontier(ids=jnp.where(valid_s, order, n), count=nnz_eff,
                     overflow=jnp.asarray(False))
    eb = expand(graph, front, cap_e, backend=backend)

    r_src = eb.slot                                   # rank of src == slot
    r_dst = jnp.minimum(rank[jnp.minimum(eb.dst, n)], nnz_eff)  # outside → N
    go = eb.valid & (r_src < r_dst)
    cut = _boundary_cut(r_src, r_dst, go, cap_n, backend)  # ∂(S_j), j=1..cap_n

    vol = ops.prefix_sum(deg_s, backend=backend)      # vol(S_j)
    denom = jnp.minimum(vol, 2 * m - vol)
    prefix_ok = valid_s & (denom > 0)
    cond = jnp.where(prefix_ok, cut / jnp.maximum(denom, 1), _INF)

    best = jnp.argmin(cond).astype(jnp.int32)
    return SweepResult(
        best_conductance=cond[best],
        best_size=best + 1,
        best_volume=vol[best],
        order=order,
        conductance=cond,
        volume=vol,
        cut=cut,
        nnz=nnz_eff,
        overflow=eb.overflow,
    )


@functools.partial(jax.jit, static_argnums=(4,),
                   static_argnames=("cap_e", "backend"))
def sweep_cut_sparse(graph: CSRGraph, ids: jnp.ndarray, vals: jnp.ndarray,
                     nnz: jnp.ndarray, cap_e: int, *,
                     backend: str = "xla") -> SweepResult:
    """Sweep over a sparse diffusion vector *without* the O(n) rank table.

    Mathematically identical to :func:`sweep_cut` — same ordering, same
    difference-array cut counting, same argmin — but the ``rank(w)`` lookup
    for edge endpoints is done by ``searchsorted`` over the support ids
    sorted ascending (O(cap_e log cap_n) work), so per-call live memory is
    O(cap_n + cap_e), independent of n.  This is the sweep the batched
    sparse backend vmaps: B lanes cost B·O(cap_n + cap_e), never B·O(n).

    Args:
      graph: CSR graph (pytree; static (n, m) key the jit cache).
      ids:  int32[cap_n] vertex ids (sentinel ``n`` beyond ``nnz``)
      vals: f32[cap_n]   diffusion mass for each id
      nnz:  int32 scalar — number of valid (id, val) pairs
      cap_e: static edge-workspace capacity (≥ vol(S_N))

    Returns a :class:`SweepResult` (same leaves/shapes as :func:`sweep_cut`).
    """
    n, m = graph.n, graph.m
    cap_n = ids.shape[0]
    arange_n = jnp.arange(cap_n, dtype=jnp.int32)
    valid = arange_n < nnz
    ids = jnp.where(valid, ids, n).astype(jnp.int32)

    deg = graph.deg[jnp.minimum(ids, n - 1)]
    deg = jnp.where(ids < n, deg, 0)
    q = jnp.where(valid & (deg > 0), vals / jnp.maximum(deg, 1), -_INF)
    perm = jnp.argsort(-q)
    order = ids[perm]
    valid_s = valid[perm] & (deg[perm] > 0)
    deg_s = jnp.where(valid_s, deg[perm], 0)
    nnz_eff = jnp.sum(valid_s).astype(jnp.int32)

    # sparse rank lookup: sort the support ids ascending, carrying their
    # sweep ranks; absent ids resolve to cap_n (≥ any rank), exactly the
    # dense table's default
    sid = jnp.where(valid_s, order, n)
    rnk = jnp.where(valid_s, arange_n, cap_n)
    asc = jnp.argsort(sid)
    sid_s = sid[asc]
    rnk_s = rnk[asc]

    front = Frontier(ids=sid, count=nnz_eff, overflow=jnp.asarray(False))
    eb = expand(graph, front, cap_e, backend=backend)

    pos = jnp.clip(jnp.searchsorted(sid_s, eb.dst), 0, cap_n - 1)
    hit = (sid_s[pos] == eb.dst) & (eb.dst < n)
    r_src = eb.slot
    r_dst = jnp.minimum(jnp.where(hit, rnk_s[pos], cap_n), nnz_eff)
    go = eb.valid & (r_src < r_dst)
    cut = _boundary_cut(r_src, r_dst, go, cap_n, backend)

    vol = ops.prefix_sum(deg_s, backend=backend)
    denom = jnp.minimum(vol, 2 * m - vol)
    prefix_ok = valid_s & (denom > 0)
    cond = jnp.where(prefix_ok, cut / jnp.maximum(denom, 1), _INF)

    best = jnp.argmin(cond).astype(jnp.int32)
    return SweepResult(
        best_conductance=cond[best],
        best_size=best + 1,
        best_volume=vol[best],
        order=order,
        conductance=cond,
        volume=vol,
        cut=cut,
        nnz=nnz_eff,
        overflow=eb.overflow,
    )


def sweep_cut_dense(graph: CSRGraph, p: jnp.ndarray, cap_n: int,
                    cap_e: int, backend: str = "xla") -> SweepResult:
    """Sweep over a dense diffusion vector: extract the top-``cap_n`` support
    first (sorted extraction = the paper's non-zero gather)."""
    n = graph.n
    cap_n = min(cap_n, n)
    nz = p > 0
    nnz = jnp.sum(nz).astype(jnp.int32)
    # take indices of the cap_n largest p/d (superset of support if it fits)
    score = jnp.where(nz, p / jnp.maximum(graph.deg, 1), -_INF)
    idx = jax.lax.top_k(score, cap_n)[1].astype(jnp.int32)
    vals = p[idx]
    count = jnp.minimum(nnz, cap_n)
    res = sweep_cut(graph, idx, vals, count, cap_e, backend=backend)
    return res._replace(overflow=res.overflow | (nnz > cap_n))
