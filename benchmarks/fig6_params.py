"""Figure 6 reproduction: runtime & conductance vs parameter settings.

Paper trends (C5), all on one graph from one seed:
  Nibble:      T↑ or ε↓  ⇒ time↑, conductance↓
  PR-Nibble:   ε↓        ⇒ time↑, conductance↓
  HK-PR:       N↑ or ε↓  ⇒ time↑, conductance↓
  rand-HK-PR:  N↑ or K↑  ⇒ time↑, conductance↓
"""
import numpy as np
import jax

from repro.core import (nibble, pr_nibble, hk_pr, rand_hk_pr, sweep_cut,
                        sweep_cut_dense)
from .common import get_graph, emit, timeit


def _cond(g, p):
    return float(sweep_cut_dense(g, p, 1 << 12, 1 << 18).best_conductance)


def run(graph_name: str = "sbm-planted", smoke: bool = False):
    g = get_graph(graph_name)
    seed = 5 if graph_name == "sbm-planted" else int(np.argmax(np.asarray(g.deg)))

    T_grid = (10,) if smoke else (5, 10, 20)
    nibble_eps = (1e-7,) if smoke else (1e-6, 1e-7, 1e-8)
    prn_eps = (1e-6,) if smoke else (1e-5, 1e-6, 1e-7)
    N_grid = (10,) if smoke else (5, 10, 20)
    hk_eps = (1e-5,) if smoke else (1e-5, 1e-7)
    NW_grid = (1024,) if smoke else (1024, 4096)
    K_grid = (10,) if smoke else (5, 10, 20)

    for T in T_grid:
        for eps in nibble_eps:
            us, res = timeit(nibble, g, seed, eps, T, repeats=1)
            emit(f"fig6/nibble/T={T},eps={eps:g}", us,
                 f"cond={_cond(g, res.p):.4f};work={int(res.edge_work)}")

    for eps in prn_eps:
        us, res = timeit(pr_nibble, g, seed, eps, 0.01, repeats=1)
        emit(f"fig6/pr_nibble/eps={eps:g}", us,
             f"cond={_cond(g, res.p):.4f};pushes={int(res.pushes)}")

    for N in N_grid:
        for eps in hk_eps:
            us, res = timeit(hk_pr, g, seed, N, eps, 10.0, repeats=1)
            emit(f"fig6/hk_pr/N={N},eps={eps:g}", us,
                 f"cond={_cond(g, res.p):.4f};work={int(res.edge_work)}")

    for NW in NW_grid:
        for K in K_grid:
            us, res = timeit(rand_hk_pr, g, seed, NW, K, 10.0,
                             jax.random.PRNGKey(0), repeats=1)
            sw = sweep_cut(g, res.ids, res.vals, res.nnz, 1 << 18)
            emit(f"fig6/rand_hk/N={NW},K={K}", us,
                 f"cond={float(sw.best_conductance):.4f}")


if __name__ == "__main__":
    run()
