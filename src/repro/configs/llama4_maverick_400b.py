"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4 family; unverified].
48L d_model=5120 40H (kv=8) d_ff=8192/expert vocab=202048."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    layer_pattern=("attn",),
    ff_kind="moe", n_experts=128, top_k=1,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
)
