"""PR-Nibble with true sparse-set state (paper-faithful memory profile).

Same algorithm as :mod:`repro.core.pr_nibble` but ``p`` and ``r`` are
:class:`SparseVec` sort-merge sparse sets instead of dense f32[n] vectors:
memory is O(cap_v) = O(|support|), independent of n — the claim that makes
the algorithms "local" in the paper.  Used to cross-check the dense backend
and to serve billion-vertex graphs where even one dense f32[n] per query is
wasteful.

Like :mod:`repro.core.pr_nibble`, the loop is decomposed into
``init / round / alive`` so the batched driver (core/batched_sparse.py) and
the serving engine (serve/cluster_engine.py) can step the *same* round
function the single-seed driver runs — that sharing is what makes their
per-seed bit-identity guarantee structural rather than aspirational.

Shape/dtype contracts (``n`` = graph.n; sentinel id is ``n``):
  * state ``p``, ``r`` — :class:`SparseVec` of capacity ``cap_v``:
    ``ids`` int32[cap_v] sorted/sentinel-padded, ``vals`` f32[cap_v],
    ``count`` int32 scalar, ``overflow`` bool scalar.
  * ``frontier`` — :class:`Frontier` of capacity ``cap_f``.
  * results carry int32 scalar ``iterations``/``pushes`` and a bool
    ``overflow`` that ORs every capacity violation (frontier, edge
    workspace, or SparseVec) seen on the way.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .frontier import Frontier, expand, pack_unique, singleton
from .sparsevec import (SparseVec, sv_empty, sv_from_pairs, sv_lookup,
                        sv_merge_add, sv_update_existing)

__all__ = ["PRNibbleSparseResult", "PRNibbleSparseState", "pr_nibble_sparse",
           "pr_nibble_sparse_fixedcap", "pr_nibble_sparse_init",
           "pr_nibble_sparse_round", "pr_nibble_sparse_alive"]


class PRNibbleSparseResult(NamedTuple):
    p: SparseVec
    r: SparseVec
    iterations: jnp.ndarray
    pushes: jnp.ndarray
    overflow: jnp.ndarray


class PRNibbleSparseState(NamedTuple):
    """Loop carry of one sparse PR-Nibble run — exposed so the batched and
    streaming drivers can step the same rounds (cf. ``PRNibbleState``)."""
    p: SparseVec
    r: SparseVec
    frontier: Frontier
    t: jnp.ndarray
    pushes: jnp.ndarray
    overflow: jnp.ndarray


def pr_nibble_sparse_init(x, n: int, cap_f: int, cap_v: int) -> PRNibbleSparseState:
    """Initial state: unit residual on the seed, seed frontier, empty p.

    ``x`` is an int32 seed id (scalar or 0-d array); the state's SparseVecs
    have capacity ``cap_v`` and the frontier capacity ``cap_f``.
    """
    r0 = sv_from_pairs(jnp.full((1,), jnp.asarray(x, jnp.int32)),
                       jnp.ones((1,), jnp.float32),
                       jnp.ones((1,), bool), cap_v, n)
    return PRNibbleSparseState(p=sv_empty(cap_v, n), r=r0,
                               frontier=singleton(x, n, cap_f),
                               t=jnp.asarray(0, jnp.int32),
                               pushes=jnp.asarray(0, jnp.int32),
                               overflow=jnp.asarray(False))


def pr_nibble_sparse_alive(s: PRNibbleSparseState,
                           max_iters: int = 10_000) -> jnp.ndarray:
    """True while the run still has above-threshold residual to push."""
    return (s.frontier.count > 0) & (~s.overflow) & (s.t < max_iters)


def pr_nibble_sparse_round(graph: CSRGraph, s: PRNibbleSparseState, eps, alpha,
                           optimized: bool, cap_e: int,
                           backend: str = "xla") -> PRNibbleSparseState:
    """One synchronous push round over the sparse state (Figures 3–4).

    ``backend`` routes both ``sv_merge_add`` reductions (the round's hot
    loop) plus the expand/pack scans through :mod:`repro.core.ops` —
    ``"pallas"`` runs them on the fused segment-merge kernel with
    bit-identical results (interpret mode off-TPU)."""
    n = graph.n
    deg = graph.deg
    f = s.frontier
    fvalid = f.valid()
    fids = jnp.where(fvalid, f.ids, n)
    safe = jnp.minimum(fids, n - 1)
    rf = jnp.where(fvalid, sv_lookup(s.r, fids, n), 0.0)
    dv = jnp.maximum(deg[safe], 1)

    if optimized:
        p_gain = (2.0 * alpha / (1.0 + alpha)) * rf
        r_self = jnp.zeros_like(rf)
        share = ((1.0 - alpha) / (1.0 + alpha)) * rf / dv
    else:
        p_gain = alpha * rf
        r_self = (1.0 - alpha) * rf / 2.0
        share = (1.0 - alpha) * rf / (2.0 * dv)

    p_new = sv_merge_add(s.p, fids, p_gain, fvalid, n, backend=backend)
    r_new = sv_update_existing(s.r, fids, r_self, fvalid)
    eb = expand(graph, f, cap_e, backend=backend)
    r_new = sv_merge_add(r_new, eb.dst, share[eb.slot], eb.valid, n,
                         backend=backend)

    cands = jnp.concatenate([fids, eb.dst])
    cvalid = jnp.concatenate([fvalid, eb.valid])
    csafe = jnp.minimum(cands, n - 1)
    r_cand = sv_lookup(r_new, cands, n)
    keep = cvalid & (deg[csafe] > 0) & (r_cand >= deg[csafe] * eps)
    nf = pack_unique(cands, keep, n, f.cap, backend=backend)

    return PRNibbleSparseState(p=p_new, r=r_new, frontier=nf, t=s.t + 1,
                               pushes=s.pushes + f.count,
                               overflow=(s.overflow | nf.overflow |
                                         eb.overflow | p_new.overflow |
                                         r_new.overflow))


@functools.partial(jax.jit, static_argnums=(4, 5, 6, 7, 8),
                   static_argnames=("optimized", "cap_f", "cap_e", "cap_v",
                                    "max_iters", "backend"))
def pr_nibble_sparse_fixedcap(graph: CSRGraph, x, eps, alpha,
                              optimized: bool, cap_f: int, cap_e: int,
                              cap_v: int, max_iters: int = 10_000, *,
                              backend: str = "xla") -> PRNibbleSparseResult:
    def cond(s: PRNibbleSparseState):
        return pr_nibble_sparse_alive(s, max_iters)

    def body(s: PRNibbleSparseState) -> PRNibbleSparseState:
        return pr_nibble_sparse_round(graph, s, eps, alpha, optimized, cap_e,
                                      backend)

    s = jax.lax.while_loop(cond, body,
                           pr_nibble_sparse_init(x, graph.n, cap_f, cap_v))
    return PRNibbleSparseResult(p=s.p, r=s.r, iterations=s.t, pushes=s.pushes,
                                overflow=s.overflow)


def pr_nibble_sparse(graph: CSRGraph, x, eps: float = 1e-7, alpha: float = 0.01,
                     optimized: bool = True, cap_f: int = 1 << 10,
                     cap_e: int = 1 << 14, cap_v: int = 1 << 12,
                     max_cap_e: int = 1 << 26,
                     backend: str = "xla") -> PRNibbleSparseResult:
    """Bucketed driver: retry with doubled capacities on overflow.

    The doubling schedule (cap_f, cap_v clamped to n+1; cap_e unclamped up to
    ``max_cap_e``) is shared verbatim by ``batched_pr_nibble_sparse`` and the
    serving engine's bucket-promotion ladder, so all three paths dispatch the
    same static shapes and return bit-identical per-seed results.
    """
    while True:
        out = pr_nibble_sparse_fixedcap(graph, x, eps, alpha, optimized,
                                        cap_f, cap_e, cap_v, backend=backend)
        if not bool(out.overflow) or cap_e >= max_cap_e:
            return out
        cap_f = min(cap_f * 2, graph.n + 1)
        cap_e *= 2
        cap_v = min(cap_v * 2, graph.n + 1)
