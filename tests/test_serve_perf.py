"""Steady-state re-trace freedom (serve/aot.py + the engine's hot path).

The serving overhaul's core claim: after ``LocalClusterEngine.warmup``, the
steady state never enters XLA again — bucket-ladder promotions hop between
already-compiled executables, and an LRU-evicted pool's re-creation is an
executable-cache hit, never a re-trace.  The guard counts actual backend
compiles through ``jax.monitoring`` (the same signal a profiler would see),
so a regression that sneaks a ``jit`` call into the tick path fails here
even if the engine's own ``aot_compiles`` accounting were wrong.
"""
import jax
import numpy as np

from repro.serve import ClusterRequest, LocalClusterEngine


def _unregister(listener) -> None:
    from jax._src import monitoring
    monitoring._unregister_event_duration_listener_by_callback(listener)


def test_steady_state_stream_never_recompiles(sbm_graph):
    # Small frontier/edge workspaces force mid-stream promotions; generous
    # sweep workspaces keep harvest on the AOT sweep (a sweep retry would
    # legitimately compile a doubled shape — that's the capacity ladder,
    # not the steady state).  lru_pools=1 forces pool eviction between the
    # two PR-Nibble statics families, so re-creation is exercised too.
    eng = LocalClusterEngine(
        sbm_graph, batch_slots=4, cap_f=1 << 8, cap_e=1 << 10,
        cap_n=1 << 10, sweep_cap_e=1 << 14, cap_v=1 << 8,
        max_cap_e=1 << 12, lru_pools=1, rounds_per_step=8)
    protos = [ClusterRequest(seed=0, optimized=True),
              ClusterRequest(seed=0, optimized=False),
              ClusterRequest(seed=0, backend="sparse")]
    w = eng.warmup(protos, max_bucket=eng.max_bucket)
    assert w["compiled"] == 3 * (eng.max_bucket + 1)
    # idempotent: a second warmup finds everything cached
    assert eng.warmup(protos, max_bucket=eng.max_bucket)["compiled"] == 0

    compiles = []

    def listener(event, duration, **kw):
        if "backend_compile" in event:
            compiles.append(event)

    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        aot_before = eng.stats["aot_compiles"]
        rng = np.random.default_rng(3)
        cand = np.flatnonzero(np.asarray(sbm_graph.deg) > 0)
        reqs = []
        for i, s in enumerate(rng.choice(cand, size=12)):
            if i % 4 == 3:
                reqs.append(ClusterRequest(seed=int(s), alpha=0.01,
                                           eps=1e-4, backend="sparse"))
            else:
                # tight-eps requests overflow the small bucket-0 workspace
                # and promote up the warmed ladder
                reqs.append(ClusterRequest(seed=int(s), alpha=0.01,
                                           eps=(1e-6 if i % 3 == 0
                                                else 1e-4),
                                           optimized=bool(i % 2)))
        eng.run(reqs)
        assert eng.stats["promotions"] > 0      # the stream hopped buckets
        # drain's trailing eviction leaves one pool; run again so evicted
        # pools are re-created — from the executable cache, not XLA
        evicted = eng.stats["pools_evicted"]
        assert evicted > 0
        hits_before = eng.stats["aot_cache_hits"]
        # drop the seed→result cache so the rerun actually re-creates
        # pools (a result-cache hit would resolve lane-free and prove
        # nothing about executable reuse)
        eng.result_cache.invalidate()
        eng.run(reqs[:6])
        assert eng.stats["aot_cache_hits"] > hits_before
        assert eng.stats["aot_compiles"] == aot_before
        assert compiles == [], (
            f"steady state entered XLA {len(compiles)} times after warmup")
    finally:
        _unregister(listener)
