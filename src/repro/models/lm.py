"""Decoder-only LM assembly: embed → pattern-scanned blocks → tied-head loss.

Layer stacking: the per-layer mixer pattern (e.g. gemma3's 5 local : 1
global) is grouped into *periods*; parameters are stacked over periods and
the stack is driven by one ``jax.lax.scan`` (compact HLO, O(1) compile cost
in depth, remat-friendly).  Remainder layers (L mod period) are applied
unrolled after the scan.

Loss never materializes [B, S, V] logits: the head runs in sequence chunks
(scan), each chunk's cross-entropy reduced immediately — the standard
large-vocab discipline (gemma3's V = 262k at S = 4k would otherwise need
34 GB per device).

Prefill emits the KV caches as scan outputs; decode scans over
(stacked params, stacked cache) updating the cache functionally.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from .layers import embed_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init
from .attention import (attn_init, attn_project_qkv, attn_output, mha,
                        decode_attend, expand_kv, full_bidir)
from .moe import moe_init, moe_apply
from .ssm import (mamba2_init, mamba2_apply, mamba2_decode_step,
                  mamba2_state_shape)
from .rglru import (rglru_init, rglru_apply, rglru_decode_step,
                    rglru_state_shapes)

__all__ = ["lm_init", "lm_loss", "lm_prefill", "lm_decode_step",
           "init_cache", "pattern_layout"]


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _mixer_init(key, cfg: ModelConfig, kind: str):
    if kind.startswith("attn"):
        return attn_init(key, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim_, cfg.param_dtype)
    if kind == "mamba2":
        return mamba2_init(key, cfg, cfg.param_dtype)
    if kind == "rglru":
        return rglru_init(key, cfg, cfg.param_dtype)
    raise ValueError(f"unknown mixer kind {kind!r}")


def block_init(key, cfg: ModelConfig, kind: str, with_cross: bool = False):
    km, kf, kc = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "norm1": rmsnorm_init(cfg.d_model),
        "mixer": _mixer_init(km, cfg, kind),
    }
    if with_cross:
        p["norm_x"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attn_init(kc, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim_, cfg.param_dtype)
    if cfg.ff_kind == "swiglu":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ff"] = swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.param_dtype)
    elif cfg.ff_kind == "moe":
        p["norm2"] = rmsnorm_init(cfg.d_model)
        p["ff"] = moe_init(kf, cfg.d_model, cfg.d_expert, cfg.n_experts,
                           cfg.param_dtype)
    return p


def block_apply(params, x, positions, kind: str, cfg: ModelConfig,
                enc_out=None):
    """Full-sequence block (train / prefill).  Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = rmsnorm(params["norm1"], x)
    if kind.startswith("attn"):
        h = mha(params["mixer"], h, positions, kind, cfg)
    elif kind == "mamba2":
        h = mamba2_apply(params["mixer"], h, cfg)
    elif kind == "rglru":
        h = rglru_apply(params["mixer"], h, cfg)
    x = x + h
    if "cross" in params and enc_out is not None:
        hc = rmsnorm(params["norm_x"], x)
        q, _, _ = attn_project_qkv(params["cross"], hc, positions, None)
        ke = jnp.einsum("bsd,dhq->bshq", enc_out, params["cross"]["wk"]["w"])
        ve = jnp.einsum("bsd,dhq->bshq", enc_out, params["cross"]["wv"]["w"])
        o = full_bidir(q, expand_kv(ke, cfg.n_heads),
                       expand_kv(ve, cfg.n_heads), cfg.kv_chunk)
        x = x + attn_output(params["cross"], o)
    if "ff" in params:
        h = rmsnorm(params["norm2"], x)
        if cfg.ff_kind == "moe":
            h, a = moe_apply(params["ff"], h, cfg.top_k, cfg.capacity_factor,
                             cfg.moe_per_row)
            aux = aux + a
        else:
            h = swiglu(params["ff"], h)
        x = x + h
    return x, aux


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def _cache_shape_for(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    dt = jnp.dtype(cfg.compute_dtype)
    if kind.startswith("attn"):
        s = min(max_seq, cfg.window + 256) if kind == "attn_local" else max_seq
        kv = (batch, s, cfg.n_kv_heads, cfg.head_dim_)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt)}
    if kind == "mamba2":
        return {"ssm": jnp.zeros(mamba2_state_shape(cfg, batch), jnp.float32)}
    if kind == "rglru":
        shp = rglru_state_shapes(cfg, batch)
        return {"h": jnp.zeros(shp["h"], jnp.float32),
                "conv": jnp.zeros(shp["conv"], jnp.dtype(cfg.compute_dtype))}
    raise ValueError(kind)


def block_decode(params, x, cache, pos, kind: str, cfg: ModelConfig,
                 enc_out=None):
    """One-token block step.  x: [B,1,D]; returns (x, new cache)."""
    h = rmsnorm(params["norm1"], x)
    new_cache = dict(cache)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    if kind.startswith("attn"):
        q, k, v = attn_project_qkv(params["mixer"], h, positions,
                                   cfg.rope_theta)
        s_cache = cache["k"].shape[1]
        # local layers keep a ring buffer of size ~window: write at pos mod
        # size; RoPE'd keys make attention order-independent so the ring
        # needs no rotation — mask by logical fill length only.
        write = pos % s_cache if kind == "attn_local" else pos
        k_new = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, write, 0, 0))
        v_new = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, write, 0, 0))
        length = jnp.minimum(pos + 1, s_cache)
        o = decode_attend(q, expand_kv(k_new, cfg.n_heads),
                          expand_kv(v_new, cfg.n_heads), length, None)
        h = attn_output(params["mixer"], o)
        new_cache["k"], new_cache["v"] = k_new, v_new
    elif kind == "mamba2":
        h, st = mamba2_decode_step(params["mixer"], h, cache["ssm"], cfg)
        new_cache["ssm"] = st
    elif kind == "rglru":
        h, st = rglru_decode_step(params["mixer"], h,
                                  {"h": cache["h"], "conv": cache["conv"]}, cfg)
        new_cache["h"], new_cache["conv"] = st["h"], st["conv"]
    x = x + h
    if "cross" in params and enc_out is not None:
        hc = rmsnorm(params["norm_x"], x)
        q, _, _ = attn_project_qkv(params["cross"], hc, positions, None)
        ke = jnp.einsum("bsd,dhq->bshq", enc_out, params["cross"]["wk"]["w"])
        ve = jnp.einsum("bsd,dhq->bshq", enc_out, params["cross"]["wv"]["w"])
        o = full_bidir(q, expand_kv(ke, cfg.n_heads),
                       expand_kv(ve, cfg.n_heads), cfg.kv_chunk)
        x = x + attn_output(params["cross"], o)
    if "ff" in params:
        h = rmsnorm(params["norm2"], x)
        if cfg.ff_kind == "moe":
            h, _ = moe_apply(params["ff"], h, cfg.top_k, cfg.capacity_factor,
                             cfg.moe_per_row)
        else:
            h = swiglu(params["ff"], h)
        x = x + h
    return x, new_cache


# --------------------------------------------------------------------------
# layer layout: scan over periods + unrolled remainder
# --------------------------------------------------------------------------

def pattern_layout(cfg: ModelConfig) -> Tuple[int, int]:
    period = len(cfg.layer_pattern)
    return cfg.n_layers // period, cfg.n_layers % period


def lm_init(key, cfg: ModelConfig, with_cross: bool = False):
    n_full, rem = pattern_layout(cfg)
    period = len(cfg.layer_pattern)
    params: Dict[str, Any] = {
        "embed": embed_init(jax.random.fold_in(key, 0), cfg.vocab,
                            cfg.d_model, cfg.param_dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    scan_params = []
    for slot, kind in enumerate(cfg.layer_pattern):
        layers = [block_init(jax.random.fold_in(key, 1 + p * period + slot),
                             cfg, kind, with_cross)
                  for p in range(n_full)]
        scan_params.append(jax.tree.map(lambda *xs: jnp.stack(xs), *layers))
    params["scan"] = tuple(scan_params)
    params["rem"] = tuple(
        block_init(jax.random.fold_in(key, 10_000 + i), cfg,
                   cfg.layer_pattern[i], with_cross)
        for i in range(rem))
    return params


def _largest_divisor_leq(n: int, k: int) -> int:
    d = min(k, n)
    while n % d != 0:
        d -= 1
    return d


def _backbone(params, x, positions, cfg: ModelConfig, enc_out=None,
              remat: bool = True, remat_group: int = 4):
    """Embeddings already applied; run all blocks.  Returns (x, aux).

    Remat is *grouped*: the period scan is reshaped to
    [n_groups, group, ...] and only the outer (group) scan is checkpointed.
    Saved residuals drop from n_layers·B·S·D to n_groups·B·S·D at the cost
    of one extra forward per group — the knob that fits train_4k activations
    in HBM at 256-way batch sharding (see EXPERIMENTS.md §Perf).
    """
    pattern = cfg.layer_pattern

    def period_body(carry, slot_params):
        x, aux = carry
        for i, kind in enumerate(pattern):
            x, a = block_apply(slot_params[i], x, positions, kind, cfg,
                               enc_out)
            aux = aux + a
        return (x, aux), None

    scan_params = params["scan"]
    n_full = jax.tree.leaves(scan_params)[0].shape[0] if \
        jax.tree.leaves(scan_params) else 0
    if n_full > 0:
        if remat:
            group = _largest_divisor_leq(n_full, remat_group)
            grouped = jax.tree.map(
                lambda a: a.reshape(n_full // group, group, *a.shape[1:]),
                scan_params)

            # nested remat: outer (group) checkpoint bounds saved residuals
            # to n_groups·B·S·D; inner (per-period) checkpoint bounds the
            # recompute-backward working set to ONE period's AD residuals.
            def group_body(carry, group_params):
                return jax.lax.scan(jax.checkpoint(period_body), carry,
                                    group_params)

            (x, aux), _ = jax.lax.scan(jax.checkpoint(group_body),
                                       (x, jnp.float32(0.0)), grouped)
        else:
            (x, aux), _ = jax.lax.scan(period_body, (x, jnp.float32(0.0)),
                                       scan_params)
    else:
        aux = jnp.float32(0.0)
    for i, p in enumerate(params["rem"]):
        x, a = block_apply(p, x, positions, pattern[i], cfg, enc_out)
        aux = aux + a
    return rmsnorm(params["final_norm"], x), aux


def _embed_tokens(params, tokens, cfg: ModelConfig):
    x = params["embed"]["w"][tokens] * jnp.asarray(
        cfg.d_model ** 0.5, jnp.dtype(cfg.compute_dtype))
    return x.astype(jnp.dtype(cfg.compute_dtype))


def lm_loss(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            loss_chunk: int = 512, remat: bool = True,
            enc_out=None) -> jnp.ndarray:
    """Mean next-token cross-entropy (labels = batch['labels'], −1 ignored)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    b, s_text = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    if cfg.n_modality_tokens and "frontend_emb" in batch:
        fe = batch["frontend_emb"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full((b, fe.shape[1]), -1, labels.dtype), labels], axis=1)
    s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h, aux = _backbone(params, x, positions, cfg, enc_out, remat)

    # chunked tied-head cross-entropy
    from .attention import pick_chunk
    emb = params["embed"]["w"]
    csz = pick_chunk(s, loss_chunk)
    nchunk = s // csz
    h_c = h.reshape(b, nchunk, csz, cfg.d_model).transpose(1, 0, 2, 3)
    l_c = labels.reshape(b, nchunk, csz).transpose(1, 0, 2)

    def chunk_ce(carry, xs):
        hc, lc = xs
        logits = jnp.einsum("bsd,vd->bsv", hc, emb,
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        ok = (lc >= 0).astype(jnp.float32)
        ce = (lse - gold) * ok
        return (carry[0] + ce.sum(), carry[1] + ok.sum()), None

    # checkpointed: backward recomputes each [B,chunk,V] logits tile instead
    # of keeping all of them live (the 16 GB/device trap at V=64k, S=4k).
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(chunk_ce),
                                 (jnp.float32(0.0), jnp.float32(0.0)),
                                 (h_c, l_c))
    return tot / jnp.maximum(cnt, 1.0) + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Functional KV/state cache pytree mirroring the scan/rem layout."""
    n_full, rem = pattern_layout(cfg)
    scan_cache = []
    for kind in cfg.layer_pattern:
        one = _cache_shape_for(cfg, kind, batch, max_seq)
        scan_cache.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_full, *x.shape)).copy() if n_full
            else x[None][:0], one))
    rem_cache = tuple(_cache_shape_for(cfg, cfg.layer_pattern[i], batch, max_seq)
                      for i in range(rem))
    return {"scan": tuple(scan_cache), "rem": rem_cache,
            "pos": jnp.asarray(0, jnp.int32)}


def lm_prefill(params, tokens, cfg: ModelConfig, max_seq: int,
               remat: bool = True, enc_out=None):
    """Full forward over the prompt; returns (cache, last-token logits)."""
    b, s = tokens.shape
    x = _embed_tokens(params, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pattern = cfg.layer_pattern
    cache0 = init_cache(cfg, b, max_seq)

    def prefill_block(p, x, kind, cache_tpl):
        h = rmsnorm(p["norm1"], x)
        new_cache = dict(cache_tpl)
        if kind.startswith("attn"):
            q, k, v = attn_project_qkv(p["mixer"], h, positions, cfg.rope_theta)
            s_cache = cache_tpl["k"].shape[1]
            kpad = k.astype(cache_tpl["k"].dtype)
            vpad = v.astype(cache_tpl["v"].dtype)
            if kind == "attn_local" and s > s_cache:
                # ring cache: position p lives at slot p % s_cache; keep the
                # trailing window, rolled so decode writes continue the ring
                kpad = jnp.roll(kpad[:, -s_cache:], s % s_cache, axis=1)
                vpad = jnp.roll(vpad[:, -s_cache:], s % s_cache, axis=1)
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache_tpl["k"], kpad, (0, 0, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache_tpl["v"], vpad, (0, 0, 0, 0))
            h = mha(p["mixer"], h, positions, kind, cfg)
        elif kind == "mamba2":
            h, st = mamba2_apply(p["mixer"], h, cfg, return_state=True)
            new_cache["ssm"] = st
        elif kind == "rglru":
            h, st = rglru_apply(p["mixer"], h, cfg, return_state=True)
            new_cache["h"] = st["h"]
            new_cache["conv"] = st["conv"].astype(cache_tpl["conv"].dtype)
        x = x + h
        if "ff" in p:
            hf = rmsnorm(p["norm2"], x)
            if cfg.ff_kind == "moe":
                hf, _ = moe_apply(p["ff"], hf, cfg.top_k, cfg.capacity_factor,
                                  cfg.moe_per_row)
            else:
                hf = swiglu(p["ff"], hf)
            x = x + hf
        return x, new_cache

    def period_body(x, xs):
        slot_params, slot_cache = xs
        new_caches = []
        for i, kind in enumerate(pattern):
            x, nc = prefill_block(slot_params[i], x, kind, slot_cache[i])
            new_caches.append(nc)
        return x, tuple(new_caches)

    body = jax.checkpoint(period_body) if remat else period_body
    x, scan_cache = jax.lax.scan(body, x, (params["scan"], cache0["scan"]))
    rem_cache = []
    for i, p in enumerate(params["rem"]):
        x, nc = prefill_block(p, x, pattern[i], cache0["rem"][i])
        rem_cache.append(nc)
    h = rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bd,vd->bv", h[:, -1], params["embed"]["w"],
                        preferred_element_type=jnp.float32)
    cache = {"scan": scan_cache, "rem": tuple(rem_cache),
             "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits


def lm_decode_step(params, token, cache, cfg: ModelConfig, enc_out=None):
    """One decode step: token [B,1] int32 → (logits [B,V], new cache)."""
    pos = cache["pos"]
    b = token.shape[0]
    x = _embed_tokens(params, token, cfg)
    pattern = cfg.layer_pattern

    def period_body(x, xs):
        slot_params, slot_cache = xs
        new_caches = []
        for i, kind in enumerate(pattern):
            x, nc = block_decode(slot_params[i], x, slot_cache[i], pos, kind,
                                 cfg, enc_out)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, scan_cache = jax.lax.scan(period_body, x,
                                 (params["scan"], cache["scan"]))
    rem_cache = []
    for i, p in enumerate(params["rem"]):
        x, nc = block_decode(p, x, cache["rem"][i], pos, pattern[i], cfg,
                             enc_out)
        rem_cache.append(nc)
    h = rmsnorm(params["final_norm"], x)
    logits = jnp.einsum("bd,vd->bv", h[:, 0], params["embed"]["w"],
                        preferred_element_type=jnp.float32)
    return logits, {"scan": scan_cache, "rem": tuple(rem_cache),
                    "pos": pos + 1}
