"""Figure 10 reproduction: network community profile (NCP) plots.

The paper generates NCPs by running PR-Nibble from many random seeds over an
(α, ε) grid; the seed loop goes through the batched multi-seed engine
(core/batched.py): one fused diffusion+sweep XLA program per batch, with
per-seed overflow retry so no seed is dropped from the profile.  The same
profile is recomputed through the memory-bounded sparse backend
(core/batched_sparse.py) as a dense-vs-sparse serving comparison.  Writes
experiments/ncp_<graph>.csv; claim C6 is the dip at the planted/community
scale.
"""
import os

import numpy as np

from repro.core import ncp
from .common import get_graph, emit, timeit

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(graph_name: str = "sbm-planted", num_seeds: int = 32,
        smoke: bool = False):
    g = get_graph(graph_name)
    if smoke:
        # smallest config: few seeds, one cold run, right-sized workspaces
        us, res = timeit(ncp, g, 8, (0.01, 0.05), (1e-6, 1e-7), 8,
                         cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                         sweep_cap_e=1 << 14, repeats=1, prime=False)
        us_sp, res_sp = timeit(ncp, g, 8, (0.01, 0.05), (1e-6, 1e-7), 8,
                               cap_f=1 << 10, cap_e=1 << 14, cap_n=1 << 10,
                               sweep_cap_e=1 << 14, backend="sparse",
                               cap_v=1 << 10, repeats=1, prime=False)
    else:
        us, res = timeit(ncp, g, num_seeds, (0.01, 0.05), (1e-6, 1e-7),
                         16, repeats=1)
        us_sp, res_sp = timeit(ncp, g, num_seeds, (0.01, 0.05), (1e-6, 1e-7),
                               16, backend="sparse", repeats=1)
    os.makedirs(OUT, exist_ok=True)
    path = os.path.join(OUT, f"ncp_{graph_name}.csv")
    with open(path, "w") as f:
        f.write("size,best_conductance\n")
        for s, c in zip(res.sizes, res.best_conductance):
            if np.isfinite(c):
                f.write(f"{s},{c:.6f}\n")
    finite = res.best_conductance[np.isfinite(res.best_conductance)]
    argmin = int(res.sizes[np.nanargmin(
        np.where(np.isfinite(res.best_conductance),
                 res.best_conductance, np.inf))])
    emit(f"fig10/{graph_name}/ncp", us,
         f"runs={res.num_runs};min_cond={finite.min():.4f};argmin_size={argmin}")
    fin_sp = res_sp.best_conductance[np.isfinite(res_sp.best_conductance)]
    min_sp = fin_sp.min() if fin_sp.size else float("inf")
    emit(f"fig10/{graph_name}/ncp_sparse", us_sp,
         f"runs={res_sp.num_runs};min_cond={min_sp:.4f};"
         f"dense_over_sparse_us={us / max(us_sp, 1e-9):.2f}")


if __name__ == "__main__":
    run()
