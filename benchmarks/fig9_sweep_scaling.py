"""Figures 8/9 reproduction: sweep cut runtime vs cluster volume.

Paper claim (C4): parallel sweep time scales ~linearly with the input
volume (the super-linear sort is a small fraction).  We grow the cluster by
loosening Nibble's ε (exactly the paper's methodology) and report µs vs
vol(S_N), plus the fitted scaling exponent.

The collected diffusion vectors are then swept again through the *batched*
sweep (core/batched.py): all curves in one vmapped XLA call, reporting the
per-seed amortized cost — the dispatch-amortization story the batched
engine is built on.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (nibble, sweep_cut_dense, batched_sweep_cut,
                        batched_sparse_sweep_cut)
from .common import get_graph, emit, timeit


def run(graph_name: str = "randLocal-50k", smoke: bool = False):
    g = get_graph("sbm-planted" if smoke else graph_name)
    if smoke:
        graph_name = "sbm-planted"
    seed = int(np.argmax(np.asarray(g.deg)))
    eps_grid = (1e-6, 1e-8) if smoke else (1e-5, 1e-6, 1e-7, 1e-8, 1e-9)
    vols, times, ps = [], [], []
    for eps in eps_grid:
        res = nibble(g, seed, eps, 20)
        p = np.asarray(res.p)
        nnz = int((p > 0).sum())
        vol = int(np.asarray(g.deg)[p > 0].sum())
        if nnz < 4:
            continue
        us, sw = timeit(sweep_cut_dense, g, res.p, 1 << 13, 1 << 19)
        emit(f"fig9/{graph_name}/eps={eps:g}", us,
             f"nnz={nnz};vol={vol};cond={float(sw.best_conductance):.4f}")
        vols.append(vol)
        times.append(us)
        ps.append(p)
    if len(vols) >= 3:
        # scaling exponent from log-log fit (≈1 = linear)
        k = np.polyfit(np.log(vols), np.log(times), 1)[0]
        emit(f"fig9/{graph_name}/scaling_exponent", 0.0, f"k={k:.2f}")
    if ps:
        # batched path: every curve's sweep in one vmapped dispatch
        batch = jnp.asarray(np.stack(ps))
        us_b, swb = timeit(batched_sweep_cut, g, batch, 1 << 13, 1 << 19)
        emit(f"fig9/{graph_name}/batched_sweep", us_b,
             f"B={len(ps)};per_seed_us={us_b / len(ps):.1f};"
             f"min_cond={float(np.min(np.asarray(swb.best_conductance))):.4f}")
        # sparse batched path: same sweeps from compacted (ids, vals) lanes —
        # per-lane memory O(cap_n + cap_e), never O(n)
        cap_n = 1 << 13
        B = len(ps)
        deg = np.asarray(g.deg)
        ids = np.full((B, cap_n), g.n, np.int32)
        vals = np.zeros((B, cap_n), np.float32)
        nnzs = np.zeros((B,), np.int32)
        truncated = 0
        for b, p in enumerate(ps):
            nz = np.flatnonzero(p > 0)
            if nz.size > cap_n:   # keep top-cap_n by p/d, like sweep_cut_dense
                score = p[nz] / np.maximum(deg[nz], 1)
                nz = nz[np.argsort(-score)[:cap_n]]
                truncated += 1
            ids[b, : nz.size] = nz
            vals[b, : nz.size] = p[nz]
            nnzs[b] = nz.size
        us_s, sws = timeit(batched_sparse_sweep_cut, g, jnp.asarray(ids),
                           jnp.asarray(vals), jnp.asarray(nnzs), 1 << 19)
        emit(f"fig9/{graph_name}/batched_sparse_sweep", us_s,
             f"B={B};per_seed_us={us_s / B:.1f};"
             f"min_cond={float(np.min(np.asarray(sws.best_conductance))):.4f};"
             f"truncated={truncated}")


if __name__ == "__main__":
    run()
