"""Distributed engine tests — run in a subprocess with 8 host devices so the
main test process keeps its single-device jax config.  Marked ``dist`` (not
``slow``) so both tier-1 and the CI dist-smoke job exercise the single-seed
driver alongside the batched one (tests/test_batched_dist.py)."""
import pytest

from conftest import run_subprocess_json

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.graphs import sbm, partition_rows
from repro.core import pr_nibble
from repro.core.distributed import dist_pr_nibble

mesh = make_host_mesh()
g = sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)
pg = partition_rows(g, 8)
res = dist_pr_nibble(pg, mesh, 5, eps=1e-6, alpha=0.05,
                     cap_f=256, cap_e=4096, cap_x=1024)
ref = pr_nibble(g, 5, eps=1e-6, alpha=0.05)
p_dist = np.asarray(res.p)[: g.n]
r_dist = np.asarray(res.r)[: g.n]
out = {
    "iters": [int(res.iterations), int(ref.iterations)],
    "pushes": [int(res.pushes), int(ref.pushes)],
    "p_maxdiff": float(np.abs(p_dist - np.asarray(ref.p)).max()),
    "p_bitident": bool((p_dist == np.asarray(ref.p)).all()),
    "mass": float(p_dist.sum() + r_dist.sum()),
    "overflow": bool(res.overflow),
    "exchanged": int(res.exchanged),
}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.dist
def test_dist_pr_nibble_matches_single_device():
    out = run_subprocess_json(_SCRIPT, timeout=600)
    assert out["iters"][0] == out["iters"][1]
    assert out["pushes"][0] == out["pushes"][1]
    assert out["p_maxdiff"] < 1e-6
    # the exchange fold order reproduces the single-chip scatter order, so
    # the distributed result is *bit*-identical (docs/algorithms.md #7)
    assert out["p_bitident"]
    assert abs(out["mass"] - 1.0) < 1e-4
    assert not out["overflow"]
    assert out["exchanged"] > 0
