#!/usr/bin/env python
"""Offline attribution report over a ``BENCH_trace.json`` flight recording.

Usage::

    python scripts/trace_report.py BENCH_trace.json [--top 5]

Renders, per lane (dense/sparse):

  * the per-request **attribution table** — latency, coverage (how much of
    the measured wall latency the recorded phases explain), and the
    per-phase breakdown (queued / pool_queue / resident / sweep / deliver);
  * the **top-k slowest** requests with their span trees, reconstructed by
    interval-nesting the Chrome trace events (the same containment rule
    Perfetto renders with);
  * **per-pool rollups** from the tick spans — ticks, total/mean tick wall
    time, mean occupancy.

The input is written by ``python -m benchmarks.serve_bench --trace`` (see
docs/architecture.md, "Observability"); the same file loads directly in
https://ui.perfetto.dev for the interactive view.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

PHASES = ("queued", "pool_queue", "resident", "sweep", "deliver")


def _fmt_ms(v):
    return "-" if v is None else f"{v:9.3f}"


def attribution_table(lane_name: str, lane: dict) -> str:
    reqs = lane.get("requests", [])
    lines = [f"== lane {lane_name} — attribution "
             f"(miss_rate={lane.get('deadline_miss_rate', 0):.3f}, "
             f"coverage min={lane.get('coverage_min')!r} "
             f"mean={lane.get('coverage_mean')!r}) =="]
    hdr = (f"{'rid':>5} {'status':>9} {'miss':>4} {'latency_ms':>10} "
           f"{'cov':>6} " + " ".join(f"{p:>10}" for p in PHASES))
    lines.append(hdr)
    lines.append("-" * len(hdr))
    totals = defaultdict(float)
    for r in reqs:
        ph = r.get("phases_ms", {})
        for p in PHASES:
            totals[p] += ph.get(p, 0.0)
        cov = r.get("coverage")
        lines.append(
            f"{r['rid']:>5} {str(r.get('status')):>9} "
            f"{'Y' if r.get('deadline_missed') else '.':>4} "
            f"{r['latency_ms']:>10.3f} "
            f"{(f'{cov:.1%}' if cov is not None else '-'):>6} "
            + " ".join(f"{ph.get(p, 0.0):>10.3f}" for p in PHASES))
    if reqs:
        lines.append("-" * len(hdr))
        lines.append(f"{'sum':>5} {'':>9} {'':>4} {'':>10} {'':>6} "
                     + " ".join(f"{totals[p]:>10.3f}" for p in PHASES))
    return "\n".join(lines)


def _nest_events(events):
    """Interval-nest complete ("X") events per (pid, tid): an event is a
    child of the tightest enclosing one, the rule trace viewers render by."""
    by_track = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            by_track[(ev.get("pid", 0), ev.get("tid", 0))].append(ev)
    trees = {}
    for track, evs in by_track.items():
        evs.sort(key=lambda e: (e["ts"], -e.get("dur", 0)))
        roots, stack = [], []
        for ev in evs:
            node = dict(ev, children=[])
            while stack and ev["ts"] + ev.get("dur", 0) > \
                    stack[-1]["ts"] + stack[-1].get("dur", 0) + 1e-9:
                stack.pop()
            (stack[-1]["children"] if stack else roots).append(node)
            stack.append(node)
        trees[track] = roots
    return trees


def _render_tree(nodes, indent=0, out=None):
    out = [] if out is None else out
    for nd in nodes:
        extras = {k: v for k, v in nd.get("args", {}).items() if k != "rid"}
        out.append("  " * indent
                   + f"{nd['name']:<12} {nd.get('dur', 0) / 1e3:9.3f} ms"
                   + (f"  {extras}" if extras else ""))
        _render_tree(nd["children"], indent + 1, out)
    return out


def slowest_requests(lane_name: str, lane: dict, events, pid: int,
                     top: int) -> str:
    reqs = sorted(lane.get("requests", []),
                  key=lambda r: -(r.get("latency_ms") or 0.0))[:top]
    trees = _nest_events(events)
    lines = [f"== lane {lane_name} — top {len(reqs)} slowest =="]
    for r in reqs:
        lines.append(f"-- rid {r['rid']}  {r['latency_ms']:.3f} ms  "
                     f"status={r.get('status')}"
                     + ("  DEADLINE MISSED" if r.get("deadline_missed")
                        else ""))
        roots = trees.get((pid, r["rid"] + 1), [])
        lines.extend(_render_tree(roots, indent=1) or ["  (no spans)"])
    return "\n".join(lines)


def pool_rollups(events) -> str:
    agg = defaultdict(lambda: dict(ticks=0, dur=0.0, occ=0.0))
    for ev in events:
        if ev.get("ph") == "X" and ev.get("name") == "tick":
            pool = ev.get("args", {}).get("pool", "?")
            a = agg[pool]
            a["ticks"] += 1
            a["dur"] += ev.get("dur", 0) / 1e3
            a["occ"] += ev.get("args", {}).get("occupancy", 0)
    lines = ["== per-pool tick rollups =="]
    hdr = f"{'pool':<48} {'ticks':>6} {'total_ms':>10} {'mean_ms':>9} " \
          f"{'mean_occ':>8}"
    lines += [hdr, "-" * len(hdr)]
    for pool, a in sorted(agg.items(), key=lambda kv: -kv[1]["dur"]):
        lines.append(f"{pool:<48} {a['ticks']:>6} {a['dur']:>10.3f} "
                     f"{a['dur'] / a['ticks']:>9.3f} "
                     f"{a['occ'] / a['ticks']:>8.2f}")
    return "\n".join(lines)


def report(artifact: dict, top: int = 5) -> str:
    events = artifact.get("traceEvents", [])
    sections = [f"trace report — schema={artifact.get('schema')} "
                f"graph={artifact.get('graph')} smoke={artifact.get('smoke')}"
                f" purity={artifact.get('purity')}"]
    for pid, (lane_name, lane) in enumerate(
            artifact.get("lanes", {}).items()):
        sections.append(attribution_table(lane_name, lane))
        sections.append(slowest_requests(
            lane_name, lane, [e for e in events if e.get("pid") == pid],
            pid, top))
        pms = lane.get("postmortems", [])
        if pms:
            sections.append(f"== lane {lane_name} — {len(pms)} deadline-miss "
                            f"postmortem(s) (span trees in the artifact) ==")
    sections.append(pool_rollups(events))
    return "\n\n".join(sections)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", nargs="?", default="BENCH_trace.json")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest requests to expand per lane")
    args = ap.parse_args()
    try:
        with open(args.path) as f:
            artifact = json.load(f)
    except OSError as e:
        print(f"cannot read {args.path}: {e}", file=sys.stderr)
        return 2
    print(report(artifact, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
