"""Mixture-of-Experts FF with sort-based dispatch (expert parallelism).

Dense one-hot dispatch (Mesh-TF style) is O(T·E·C) and collapses at
E=384 (kimi-k2).  We use the sort-based route (MaxText/Megablocks style):

  1. top-k routing: (token, expert, gate) triples, T·k of them;
  2. sort triples by expert id; per-expert segment offsets via searchsorted;
  3. gather tokens into [E, C, D] expert batches (capacity C with
     overflow-drop — the standard capacity-factor contract);
  4. batched expert SwiGLU [E,C,D]·[E,D,F] einsums — experts shard over the
     `model` axis (EP), so under GSPMD the gather/scatter become all-to-alls;
  5. scatter-add back with gate weights.

The routing sort + segment machinery is the same sort/prefix-sum vocabulary
as the paper's frontier packing (frontier.py) — one framework, one idiom.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d_model, d_expert, n_experts, dtype="bfloat16"):
    kr, ki, kg, ko = jax.random.split(key, 4)
    scale_in = d_model ** -0.5
    scale_out = d_expert ** -0.5
    def w(k, shape, s):
        return (jax.random.normal(k, shape, jnp.float32) * s).astype(dtype)
    return {
        "router": dense_init(kr, (d_model,), (n_experts,), "float32"),
        "wi_e": {"w": w(ki, (n_experts, d_model, d_expert), scale_in)},
        "wg_e": {"w": w(kg, (n_experts, d_model, d_expert), scale_in)},
        "wo_e": {"w": w(ko, (n_experts, d_expert, d_model), scale_out)},
    }


def moe_apply(params, x, top_k: int, capacity_factor: float = 1.25,
              per_row: bool = False):
    """x: [B, S, D] -> [B, S, D] plus aux load-balance loss.

    ``per_row=True`` dispatches each batch row independently (vmap over B):
    the routing sort/argsort/searchsorted stay *local to the batch shard*
    under GSPMD instead of operating on the globally-concatenated token
    axis — removing the all-gather of router state that otherwise dominates
    collective time at large T (see EXPERIMENTS.md §Perf, llama4 prefill).
    """
    if per_row:
        def one_row(xr):
            out, aux = moe_apply(params, xr[None], top_k, capacity_factor,
                                 per_row=False)
            return out[0], aux
        outs, auxs = jax.vmap(one_row)(x)
        return outs, jnp.mean(auxs)
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e = params["wi_e"]["w"].shape[0]

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)   # [t, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ----
    flat_expert = expert_ids.reshape(-1)                  # [t*k]
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]

    cap = int(capacity_factor * t * top_k / e) + 1
    start = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32), side="left")
    rank = jnp.arange(t * top_k, dtype=jnp.int32) - start[se]
    keep = rank < cap                                     # capacity drop

    # gather into [e, cap] token index table (sentinel t = dropped slot)
    slot = se * cap + rank
    token_tbl = jnp.full((e * cap,), t, jnp.int32).at[
        jnp.where(keep, slot, e * cap)].set(st, mode="drop")
    gate_tbl = jnp.zeros((e * cap,), jnp.float32).at[
        jnp.where(keep, slot, e * cap)].set(sg, mode="drop")
    token_tbl = token_tbl.reshape(e, cap)
    gate_tbl = gate_tbl.reshape(e, cap)

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    xe = xt_pad[token_tbl]                                # [e, cap, d]

    # batched expert SwiGLU
    h = jnp.einsum("ecd,edf->ecf", xe, params["wi_e"]["w"])
    g = jnp.einsum("ecd,edf->ecf", xe, params["wg_e"]["w"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    ye = jnp.einsum("ecf,efd->ecd", h, params["wo_e"]["w"])
    ye = ye * gate_tbl[..., None].astype(ye.dtype)

    # scatter back
    yt = jnp.zeros((t + 1, d), jnp.float32).at[token_tbl.reshape(-1)].add(
        ye.reshape(e * cap, d).astype(jnp.float32))
    out = yt[:t].reshape(b, s, d).astype(x.dtype)

    # load-balance aux loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[flat_expert].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)
    return out, aux
