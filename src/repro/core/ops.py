"""Unified kernel-dispatch layer for the four hot primitives (``core.ops``).

Every hot loop in the drivers bottoms out in one of four primitives — the
paper's §3 vocabulary, restated as ops:

  ============== ====================================== =====================
  op             paper primitive                        Pallas kernel
  ============== ====================================== =====================
  scatter_add    atomic fetchAdd (batched)              kernels/scatter_accum
  segment_merge  sparse-set batch insert (sort-merge)   kernels/segment_merge
  diffusion_spmv saturated push round (A D⁻¹ p)         kernels/ell_spmv
  prefix_sum     prefix sum (Blelloch scan)             kernels/prefix_scan
  ============== ====================================== =====================

This module is the single seam between the drivers (frontier / sparsevec /
sweep / pr_nibble / batched / distributed / serving) and the kernels: a
driver never names a kernel, it names an op and a *backend*.

Backends
--------
``"xla"``
    The reference: plain jnp/XLA scatter, sort + ``segment_sum``, gather
    SpMV, ``jnp.cumsum`` — byte-for-byte the pre-op-layer driver code.
``"pallas"``
    The MXU kernels (interpret mode off-TPU, so the same code path is
    exercised in CI on CPU).  Fold orders are preserved (stable sort +
    in-order one-hot contraction + carried left folds), so ``scatter_add``
    and ``segment_merge`` are *bit-identical* to ``xla`` in interpret mode,
    and ``prefix_sum`` is bit-identical for the integer dtypes the drivers
    scan (associativity is exact in int arithmetic).  ``diffusion_spmv``
    reassociates the banded row reduction and is allclose, not bit-equal.
``"auto"``
    Resolves once at trace time: ``pallas`` on TPU, ``xla`` elsewhere.

Two trace-time guards keep ``pallas`` exact and deployable at the capacity
ladder's extremes: integer ``scatter_add`` stays on the XLA scatter (an f32
MXU round-trip is only exact below 2²⁴ and ints gain nothing from the MXU),
and ``segment_merge`` streams longer than ``_MERGE_PALLAS_MAX_STREAM`` fall
back to the XLA merge (the fused kernel holds the stream in VMEM).  Both
fallbacks are bit-identical by the invariant above, so they are pure
performance decisions.

Extending: :func:`register_backend` installs a new named implementation set
(e.g. a sharded scatter, an HK-PR sparse-state merge) without touching any
driver — they all take ``backend=`` and pass it here.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.segment_merge import segment_merge_sorted

__all__ = ["OPS", "backends", "register_backend", "resolve",
           "scatter_add", "segment_merge", "diffusion_spmv", "prefix_sum",
           "graph_degrees", "graph_expand", "local_csr"]

OPS = ("scatter_add", "segment_merge", "diffusion_spmv", "prefix_sum")

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register_backend(name: str, **impls) -> None:
    """Register implementations for (a subset of) :data:`OPS` under ``name``.

    Missing ops fall back to the ``xla`` reference, so a backend can swap in
    one kernel at a time."""
    unknown = set(impls) - set(OPS)
    if unknown:
        raise ValueError(f"unknown ops {sorted(unknown)}; valid: {OPS}")
    table = dict(_REGISTRY.get("xla", {}))
    table.update(impls)
    _REGISTRY[name] = table


def backends() -> tuple:
    return tuple(_REGISTRY)


def resolve(backend: str) -> str:
    """Concrete backend name for ``backend`` ("auto" → TPU? pallas : xla)."""
    if backend is None or backend == "auto":
        return "pallas" if kops.on_tpu() else "xla"
    if backend not in _REGISTRY:
        raise ValueError(
            f"unknown ops backend {backend!r}; registered: {backends()}")
    return backend


def _impl(op: str, backend: str) -> Callable:
    return _REGISTRY[resolve(backend)][op]


# ------------------------------------------------------------------- the ops

def scatter_add(vec, idx, vals, valid=None, *, backend: str = "xla"):
    """Masked ``vec.at[idx].add(vals)`` — the batched fetchAdd.

    ``valid`` masks both the index (dropped via the shared sentinel
    ``vec.shape[0]``) and the value; ``None`` means all valid.  Any dtype;
    the result keeps ``vec``'s dtype.  Backends agree bitwise (see module
    docstring)."""
    if valid is None:
        valid = jnp.ones(idx.shape, bool)
    return _impl("scatter_add", backend)(vec, idx, vals, valid)


def segment_merge(ids, vals, n: int, cap: int, *, backend: str = "xla"):
    """Sum duplicate ids of an unsorted stream; compact to ``cap`` slots.

    ``ids`` int32[tot] with sentinel ``n`` marking dropped entries, ``vals``
    f32[tot].  Returns ``(out_ids int32[cap], out_vals f32[cap],
    count int32)`` — unique ids ascending, per-id totals folded in stream
    order, sentinel/zero padded; ``count`` is uncapped so callers detect
    overflow as ``count > cap``.  This is the body of
    :func:`repro.core.sparsevec.sv_merge_add`."""
    return _impl("segment_merge", backend)(ids, vals, n, cap)


def diffusion_spmv(nbr, wgt, esc_src, esc_dst, esc_w, p, halo: int = 1, *,
                   backend: str = "xla"):
    """One saturated diffusion product y = coef·(A D⁻¹)p on the hybrid
    banded-ELL + escaper-COO layout of :func:`repro.kernels.ops.pack_banded_ell`."""
    return _impl("diffusion_spmv", backend)(nbr, wgt, esc_src, esc_dst,
                                            esc_w, p, halo)


def prefix_sum(x, *, backend: str = "xla"):
    """Inclusive prefix sum, dtype preserved (int scans are exact on every
    backend; f32 scans may reassociate on ``pallas``)."""
    return _impl("prefix_sum", backend)(x)


# ------------------------------------------------------- the graph seam
# Host-level drivers stop assuming a resident CSR: they ask these dispatchers,
# which accept any graph-like (CSRGraph | PartitionedCSR | GraphHandle — see
# repro.graphs.handle) and route to the representation that can answer.
# Imports are lazy: frontier.py imports this module, and the graphs package
# must stay importable without core.

def graph_degrees(graph):
    """Host int32[n] degree vector of any graph-like, without materializing a
    resident CSR (partition slabs already carry degrees)."""
    from repro.graphs.handle import as_handle
    return as_handle(graph).degrees()


def local_csr(graph):
    """The resident-CSR view of any graph-like (materialized + cached from
    the partition slabs when the handle was built sharded-first)."""
    from repro.graphs.handle import as_local_csr
    return as_local_csr(graph)


def graph_expand(graph, frontier, cap_e: int, *, backend: str = "xla"):
    """Neighborhood expansion (EDGEMAP) of ``frontier`` against any
    graph-like.  Local handles route to :func:`repro.core.frontier.expand`;
    a sharded-only handle raises — per-shard expansion belongs to the
    distributed drivers (`repro.core.batched_dist` /
    `repro.core.distributed`), which own the exchange collective."""
    from repro.graphs.handle import as_handle
    from .frontier import expand
    handle = as_handle(graph)   # coerce first: bare PartitionedCSR included
    if handle.is_sharded and not handle.has_local:
        raise ValueError(
            "graph_expand needs a resident CSR; this graph is sharded-only "
            "— use the distributed drivers, or handle.local() to gather")
    return expand(handle.local(), frontier, cap_e, backend=backend)


# ------------------------------------------------------------ xla (reference)

def _scatter_add_xla(vec, idx, vals, valid):
    safe = jnp.where(valid, idx, vec.shape[0])
    return vec.at[safe].add(jnp.where(valid, vals, 0).astype(vec.dtype),
                            mode="drop")


def _segment_merge_xla(ids, vals, n, cap):
    # sort → adjacent-duplicate groups → segment_sum → prefix-sum compaction:
    # verbatim the pre-op-layer sv_merge_add body (the bit-identity reference)
    tot = ids.shape[0]
    order = jnp.argsort(ids)
    ids_s = ids[order]
    vals_s = vals[order]
    first = jnp.concatenate([jnp.array([True]), ids_s[1:] != ids_s[:-1]])
    group = jnp.cumsum(first) - 1
    sums = jax.ops.segment_sum(vals_s, group, num_segments=tot)
    sel = first & (ids_s < n)
    pos = jnp.cumsum(sel) - 1
    count = jnp.sum(sel).astype(jnp.int32)
    out_ids = jnp.full((cap,), n, jnp.int32).at[
        jnp.where(sel, pos, cap)].set(ids_s, mode="drop")
    out_vals = jnp.zeros((cap,), jnp.float32).at[
        jnp.where(sel, pos, cap)].set(sums[group], mode="drop")
    return out_ids, out_vals, count


def _diffusion_spmv_xla(nbr, wgt, esc_src, esc_dst, esc_w, p, halo):
    n_pad = p.shape[0]
    safe = jnp.clip(nbr, 0, n_pad - 1)
    y = jnp.sum(jnp.where(nbr < n_pad, wgt * p[safe], 0.0), axis=1)
    return y.at[esc_src].add(esc_w * p[esc_dst])


def _prefix_sum_xla(x):
    return jnp.cumsum(x)


register_backend("xla",
                 scatter_add=_scatter_add_xla,
                 segment_merge=_segment_merge_xla,
                 diffusion_spmv=_diffusion_spmv_xla,
                 prefix_sum=_prefix_sum_xla)


# ------------------------------------------------------------------- pallas

_MERGE_PALLAS_MAX_STREAM = 1 << 20  # VMEM bound: the kernel holds the stream


def _scatter_add_pallas(vec, idx, vals, valid):
    if not jnp.issubdtype(vec.dtype, jnp.floating):
        # integer scatters gain nothing from the MXU and would round-trip
        # through f32 (exact only below 2^24, which the capacity-ladder
        # extremes can exceed) — keep them on the always-exact XLA scatter
        return _scatter_add_xla(vec, idx, vals, valid)
    cap = vec.shape[0]
    safe = jnp.where(valid, idx, cap).astype(jnp.int32)
    masked = jnp.where(valid, vals, 0)
    out = kops.scatter_fold_via_mxu(vec.astype(jnp.float32), safe,
                                    masked.astype(jnp.float32))
    return out.astype(vec.dtype)


def _segment_merge_pallas(ids, vals, n, cap):
    if ids.shape[0] > _MERGE_PALLAS_MAX_STREAM:
        # the fused kernel keeps the whole stream in VMEM; ladder-extreme
        # buckets (cap_e ≳ 2^20) stay on the xla merge (trace-time branch —
        # shapes are static, so this costs nothing and results are
        # bit-identical either way)
        return _segment_merge_xla(ids, vals, n, cap)
    order = jnp.argsort(ids)                 # same stable sort as xla
    return segment_merge_sorted(ids[order].astype(jnp.int32),
                                vals[order].astype(jnp.float32), n, cap,
                                interpret=not kops.on_tpu())


def _diffusion_spmv_pallas(nbr, wgt, esc_src, esc_dst, esc_w, p, halo):
    return kops.diffusion_spmv(nbr, wgt, esc_src, esc_dst, esc_w, p,
                               halo=halo)


register_backend("pallas",
                 scatter_add=_scatter_add_pallas,
                 segment_merge=_segment_merge_pallas,
                 diffusion_spmv=_diffusion_spmv_pallas,
                 prefix_sum=kops.prefix_sum_exact)
