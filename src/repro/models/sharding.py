"""Path-based sharding rules: param/cache pytrees → PartitionSpec trees.

Logical 3-D mesh ``(pod, data, model)`` (mesh.py):
  * batch            → ("pod", "data")   (replicated when batch == 1)
  * vocab / heads / FF hidden / experts / recurrent width → "model"
  * layer-stack leading axis (scan) → unsharded

Rules are matched against the flattened tree path (joined with '/'), first
match wins — the same convention as t5x/MaxText logical-axis rules, without
requiring a parameter framework.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["param_specs", "cache_specs", "batch_axes", "MODEL_AXIS"]

MODEL_AXIS = "model"
DATA_AXES = ("pod", "data")


def batch_axes(global_batch: int, mesh) -> Optional[Tuple[str, ...]]:
    """Batch sharding axes, dropping axes that don't divide the batch."""
    axes = [a for a in DATA_AXES if a in mesh.shape]
    keep = []
    b = global_batch
    for a in axes:
        if b % mesh.shape[a] == 0 and mesh.shape[a] > 1:
            keep.append(a)
            b //= mesh.shape[a]
    if not keep:
        return None
    return tuple(keep)


# (path regex, trailing-dims spec). Specs align from the RIGHT so the
# scanned layer-stack leading axes are implicitly None.  First match wins.
_PARAM_RULES = [
    (r"embed/w$", P(MODEL_AXIS, None)),
    (r"(wq|wk|wv)/w$", P(None, MODEL_AXIS, None)),   # [D,H,Dh]
    (r"(mixer|cross)/wo/w$", P(MODEL_AXIS, None, None)),  # attn out [H,Dh,D]
    (r"ff/router/w$", P(None, None)),
    (r"(wi_e|wg_e)/w$", P(MODEL_AXIS, None, None)),  # moe [E,D,F] — EP
    (r"wo_e/w$", P(MODEL_AXIS, None, None)),         # moe [E,F,D] — EP
    (r"ff/(wi|wg)/w$", P(None, MODEL_AXIS)),         # swiglu [D,F]
    (r"ff/wo/w$", P(MODEL_AXIS, None)),              # swiglu [F,D]
    (r"in_proj/w$", P(None, MODEL_AXIS)),            # mamba fused in
    (r"out_proj/w$", P(MODEL_AXIS, None)),           # mamba out
    (r"(A_log|D|dt_bias)$", P(None)),                # small vectors: replicate
    (r"(in_x|in_gate|w_a|w_i)/w$", P(None, MODEL_AXIS)),
    (r"conv_w$", P(None, MODEL_AXIS)),
    (r"lam$", P(MODEL_AXIS)),
    (r"mixer/out/w$", P(MODEL_AXIS, None)),          # rglru out [W,D]
    (r"(norm|norm1|norm2|norm_x|final_norm|enc_norm)(/scale)?$", None),
    (r"scale$", None),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(k.name)
        else:
            parts.append(str(k))
    return "/".join(parts)


def _repair(spec_t, shape, model_size: int, allow_move: bool = True):
    """Divisibility repair: a dim carrying 'model' must divide |model|.

    If it doesn't (llama4: 40 Q heads on a 16-wide axis), either move the
    axis to the rightmost unsharded dim that divides (head_dim), or — for
    K/V (``allow_move=False``) — drop it: with the repeat-KV attention form,
    replicated K/V projections + model-sharded Q is the clean GQA TP layout
    (the repeat slices locally), whereas Dh-sharded K/V forces resharding.
    """
    dims = list(spec_t)
    for d, ax in enumerate(dims):
        if ax != MODEL_AXIS:
            continue
        if shape[d] % model_size == 0 and shape[d] >= model_size:
            continue
        dims[d] = None
        if not allow_move:
            continue
        for alt in range(len(dims) - 1, -1, -1):
            if dims[alt] is None and shape[alt] % model_size == 0 \
                    and shape[alt] >= model_size:
                dims[alt] = MODEL_AXIS
                break
    return tuple(dims)


# Q/K/V/O: when the head count doesn't divide the model axis, REPLICATE
# rather than shard head_dim — Dh-sharded attention forces an all-reduce on
# every score tile (measured 16.7 TB/step on llama4 prefill_32k; §Perf).
_NO_MOVE = re.compile(r"(wq|wk|wv|wo)/w$")


def _match(path_s: str, shape, model_size: int):
    ndim = len(shape)
    for pat, spec in _PARAM_RULES:
        if re.search(pat, path_s):
            if spec is None:
                return P()
            spec_t = tuple(spec)
            # pad/trim to ndim from the left (stacked layer axes = None)
            if len(spec_t) < ndim:
                spec_t = (None,) * (ndim - len(spec_t)) + spec_t
            elif len(spec_t) > ndim:
                spec_t = spec_t[-ndim:]
            allow_move = not _NO_MOVE.search(path_s)
            return P(*_repair(spec_t, shape, model_size, allow_move))
    return P()  # default: replicate


def param_specs(params_shape, mesh=None) -> "jax.tree_util.PyTreeDef":
    """Build a PartitionSpec tree for a params (shape) pytree."""
    model_size = mesh.shape[MODEL_AXIS] if mesh is not None and \
        MODEL_AXIS in mesh.shape else 16

    def one(path, leaf):
        return _match(_path_str(path), leaf.shape, model_size)
    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_specs(cache_shape, batch_spec, mesh=None):
    """Specs for the serve cache (with the same divisibility repair).

    k/v [.., B, S, Kv, Dh] → (.., batch, None, model, None)
    ssm [.., B, H, P, N]   → (.., batch, model, None, None)
    h   [.., B, W]         → (.., batch, model)
    conv[.., B, K−1, W]    → (.., batch, None, model)
    pos scalar             → replicated
    """
    model_size = mesh.shape[MODEL_AXIS] if mesh is not None and \
        MODEL_AXIS in mesh.shape else 16

    def _core(ps: str):
        if re.search(r"(^|/)k$|(^|/)v$", ps):
            return (batch_spec, None, MODEL_AXIS, None)
        if ps.endswith("ssm"):
            return (batch_spec, MODEL_AXIS, None, None)
        if ps.endswith("conv"):
            return (batch_spec, None, MODEL_AXIS)
        if ps.endswith("/h") or ps == "h":
            return (batch_spec, MODEL_AXIS)
        if ps.endswith("enc_out"):
            return (batch_spec, None, None)
        return None

    def one(path, leaf):
        ps = _path_str(path)
        if ps.endswith("pos"):
            return P()
        core = _core(ps)
        if core is None:
            return P()
        nd = len(leaf.shape)
        spec_t = (None,) * (nd - len(core)) + core
        # repair only the MODEL dims (batch spec handled by batch_axes)
        fixed = []
        for d, ax in enumerate(spec_t):
            if ax == MODEL_AXIS and (leaf.shape[d] % model_size != 0
                                     or leaf.shape[d] < model_size):
                fixed.append(None)
                continue
            fixed.append(ax)
        # K/V caches: never move the axis (repeat-KV wants replicated KV
        # when head count doesn't divide); states (ssm/h/conv) may move.
        if not re.search(r"(^|/)k$|(^|/)v$", ps):
            fixed = _try_move_model(fixed, spec_t, leaf.shape, model_size)
        return P(*fixed)

    def _try_move_model(fixed, orig, shape, model_size):
        if MODEL_AXIS in fixed or MODEL_AXIS not in orig:
            return fixed
        for alt in range(len(fixed) - 1, 0, -1):  # never the batch dim 0-ish
            if fixed[alt] is None and shape[alt] % model_size == 0 \
                    and shape[alt] >= model_size:
                fixed[alt] = MODEL_AXIS
                break
        return fixed

    return jax.tree_util.tree_map_with_path(one, cache_shape)
