"""Network Community Profile driver (paper §5, Figure 10).

NCP(s) = best conductance over all found clusters of size s.  The paper
generates it by running PR-Nibble from 10⁵ random seeds over a grid of
(α, ε) and sweeping each output — "a straightforward way to use parallelism
is to run many local graph computations independently in parallel".

The outer loop rides the batched multi-seed subsystem
(:mod:`repro.core.batched`): each batch of seeds runs as one XLA program
through the fused diffusion+sweep kernel, and seeds whose frontier
overflowed the capacity bucket are retried at the next power-of-two bucket
instead of being dropped — every seed contributes to the profile.  Batches
are sharded over the `data` mesh axis by the distributed launcher; this is
the multi-pod embodiment of the paper's interactive-analytics workload.

``backend="sparse"`` swaps in the memory-bounded fused kernel from
:mod:`repro.core.batched_sparse` — same profile semantics, per-lane state
O(cap_v) instead of O(n).
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.graphs.handle import as_handle
from . import ops as core_ops
from .batched import batched_cluster, batched_cluster_fixedcap
from .batched_dist import batched_cluster_dist
from .batched_sparse import batched_cluster_sparse

__all__ = ["NCPResult", "ncp_batch", "ncp"]


class NCPResult(NamedTuple):
    sizes: np.ndarray         # int — cluster size grid (1..max)
    best_conductance: np.ndarray  # f32 per size (inf where none found)
    num_runs: int


def ncp_batch(graph: CSRGraph, seeds: jnp.ndarray, params: jnp.ndarray,
              cap_f: int, cap_e: int, cap_n: int, sweep_cap_e: int):
    """One vmapped batch: seeds[i] with (eps, alpha) = params[i].

    Kept for API compatibility; delegates to the fused batched kernel.
    Returns per-run (conductances[cap_n], support, overflow) — the full
    sweep curve so every prefix feeds the NCP, not just the argmin.
    """
    out = batched_cluster_fixedcap(graph, seeds, params[:, 0], params[:, 1],
                                   True, cap_f, cap_e, min(cap_n, graph.n),
                                   sweep_cap_e)
    return out.conductance, out.support, out.overflow


def ncp(graph, num_seeds: int = 256,
        alphas=(0.1, 0.01), epss=(1e-5, 1e-6, 1e-7),
        batch: int = 64, seed: int = 0,
        cap_f: int = 1 << 12, cap_e: int = 1 << 16,
        cap_n: int = 1 << 12, sweep_cap_e: int = 1 << 18,
        backend: str = "dense", cap_v: int = 1 << 12,
        ops_backend: str = "xla", mesh=None,
        dist_axis: str = "data") -> NCPResult:
    """Host driver: grid of (seed, α, ε) runs through the batched engine
    (per-seed overflow retry included).  ``graph`` is any graph-like
    (``CSRGraph`` or :class:`~repro.graphs.handle.GraphHandle`).

    ``backend="sparse"`` routes every batch through the fused sparse path
    (:func:`repro.core.batched_sparse.batched_cluster_sparse`): per-lane
    memory O(cap_v) instead of O(n), sweep curves on the
    ``min(cap_n, cap_v)`` grid — the profile a billion-vertex NCP must use.

    ``backend="dist"`` shards every batch over the handle's mesh
    (:func:`repro.core.batched_dist.batched_cluster_dist`) — the multi-host
    NCP sweep.  Per-seed diffusions are bit-identical to the dense path, so
    the profile is too.

    ``ops_backend`` ("xla" | "pallas" | "auto") is orthogonal to the lane
    choice: it selects the kernel backend every scatter/merge/scan inside
    either path dispatches through (:mod:`repro.core.ops`); profiles are
    bit-identical across ops backends.
    """
    if backend not in ("dense", "sparse", "dist"):
        raise ValueError(f"unknown backend: {backend!r}")
    handle = as_handle(graph, mesh=mesh, axis=dist_axis)
    ops_backend = core_ops.resolve(ops_backend)
    rng = np.random.default_rng(seed)
    deg = core_ops.graph_degrees(handle)
    nonzero = np.flatnonzero(deg > 0)
    seeds = rng.choice(nonzero, size=num_seeds, replace=True).astype(np.int32)
    grid = [(e, a) for a in alphas for e in epss]

    n = handle.n
    cap_n = min(cap_n, n)         # sweep clamps its prefix cap to n
    if backend == "sparse":
        cap_n = min(cap_n, cap_v)  # sparse curves live on the cap_v grid
    best = np.full((cap_n,), np.inf, dtype=np.float32)
    runs = 0
    for (eps, alpha) in grid:
        for lo in range(0, num_seeds, batch):
            sb = seeds[lo: lo + batch]
            if sb.shape[0] < batch:  # pad final batch
                sb = np.concatenate([sb, np.repeat(sb[:1], batch - sb.shape[0])])
            if backend == "sparse":
                out = batched_cluster_sparse(handle.local(), sb, eps, alpha,
                                             cap_f=cap_f, cap_e=cap_e,
                                             cap_v=cap_v,
                                             sweep_cap_e=sweep_cap_e,
                                             backend=ops_backend)
            elif backend == "dist":
                out = batched_cluster_dist(handle, sb, eps, alpha,
                                           cap_f=cap_f, cap_e=cap_e,
                                           cap_n=cap_n,
                                           sweep_cap_e=sweep_cap_e,
                                           backend=ops_backend)
            else:
                out = batched_cluster(handle.local(), sb, eps, alpha,
                                      cap_f=cap_f, cap_e=cap_e, cap_n=cap_n,
                                      sweep_cap_e=sweep_cap_e,
                                      backend=ops_backend)
            ok = ~out.overflow
            curves = np.where(ok[:, None], out.conductance[:, :cap_n], np.inf)
            best = np.minimum(best, curves.min(axis=0))
            runs += int(ok.sum())
    sizes = np.arange(1, cap_n + 1)
    return NCPResult(sizes=sizes, best_conductance=best, num_runs=runs)
