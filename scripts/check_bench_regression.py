#!/usr/bin/env python
"""Serving-latency regression gate over ``BENCH_serve.json`` smoke numbers.

Usage::

    python scripts/check_bench_regression.py BENCH_serve.json
    python scripts/check_bench_regression.py BENCH_serve.json --update

Compares each lane's ``deadline_miss_rate`` and ``p99_ms`` against the
committed baseline (``benchmarks/baselines/serve_smoke.json``) with
tolerance bands sized for shared CI runners — the gate catches *collapses*
(a lane that stops meeting deadlines, a p99 that blows up by multiples),
not noise:

  * miss rate may exceed the baseline by at most ``miss_rate_slack``
    (absolute, default 0.10);
  * miss rate may never exceed ``miss_rate_max`` (absolute ceiling,
    default 0.05 — the serving SLO: even a "passing" drift relative to a
    rotten baseline must still meet deadlines 95% of the time);
  * p99 may exceed the baseline by at most ``p99_ratio``× (default 4×).

Getting *better* never fails the gate; refresh the committed baseline with
``--update`` when an improvement should become the new floor.  Exits 0 on
pass, 1 on regression, 2 on unusable input (missing file / lane mismatch) —
CI treats nonzero as failure either way.
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINE = "benchmarks/baselines/serve_smoke.json"
MISS_RATE_SLACK = 0.10   # absolute headroom over baseline miss rate
MISS_RATE_MAX = 0.05     # absolute SLO ceiling, baseline-independent
P99_RATIO = 4.0          # multiplicative headroom over baseline p99


def extract(artifact: dict) -> dict:
    """lane → the two gated numbers."""
    lanes = artifact.get("lanes", {})
    return {name: dict(deadline_miss_rate=lane["deadline_miss_rate"],
                       p99_ms=lane["p99_ms"])
            for name, lane in lanes.items()}


def compare(fresh: dict, baseline: dict, miss_rate_slack: float,
            p99_ratio: float, miss_rate_max: float = MISS_RATE_MAX) -> list:
    failures = []
    for lane, base in baseline["lanes"].items():
        cur = fresh.get(lane)
        if cur is None:
            failures.append(f"lane {lane!r}: present in baseline, missing "
                            f"from the fresh artifact")
            continue
        if cur["deadline_miss_rate"] > miss_rate_max:
            failures.append(
                f"lane {lane!r}: deadline_miss_rate "
                f"{cur['deadline_miss_rate']:.3f} > {miss_rate_max:.3f} "
                f"SLO ceiling (--miss-rate-max)")
        miss_cap = base["deadline_miss_rate"] + miss_rate_slack
        if cur["deadline_miss_rate"] > miss_cap:
            failures.append(
                f"lane {lane!r}: deadline_miss_rate "
                f"{cur['deadline_miss_rate']:.3f} > {miss_cap:.3f} "
                f"(baseline {base['deadline_miss_rate']:.3f} "
                f"+ {miss_rate_slack} slack)")
        p99_cap = base["p99_ms"] * p99_ratio
        if cur["p99_ms"] > p99_cap:
            failures.append(
                f"lane {lane!r}: p99_ms {cur['p99_ms']:.1f} > "
                f"{p99_cap:.1f} (baseline {base['p99_ms']:.1f} "
                f"× {p99_ratio})")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="?", default="BENCH_serve.json",
                    help="freshly produced serve artifact")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--miss-rate-slack", type=float,
                    default=MISS_RATE_SLACK)
    ap.add_argument("--miss-rate-max", type=float, default=MISS_RATE_MAX,
                    help="absolute deadline-miss ceiling per lane "
                         "(the serving SLO, checked against the fresh "
                         "artifact regardless of baseline)")
    ap.add_argument("--p99-ratio", type=float, default=P99_RATIO)
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the fresh artifact")
    args = ap.parse_args()

    try:
        with open(args.fresh) as f:
            fresh_artifact = json.load(f)
    except OSError as e:
        print(f"cannot read fresh artifact {args.fresh}: {e}",
              file=sys.stderr)
        return 2
    fresh = extract(fresh_artifact)
    if not fresh:
        print(f"{args.fresh} has no lanes to gate", file=sys.stderr)
        return 2

    if args.update:
        baseline = dict(schema="repro.bench.baseline/v1",
                        source=args.fresh,
                        smoke=fresh_artifact.get("smoke"),
                        graph=fresh_artifact.get("graph"),
                        lanes=fresh)
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"baseline updated: {args.baseline}")
        return 0

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except OSError as e:
        print(f"cannot read baseline {args.baseline}: {e} "
              f"(generate one with --update)", file=sys.stderr)
        return 2

    failures = compare(fresh, baseline, args.miss_rate_slack, args.p99_ratio,
                       miss_rate_max=args.miss_rate_max)
    for lane, cur in sorted(fresh.items()):
        base = baseline["lanes"].get(lane, {})
        print(f"lane {lane}: miss_rate {cur['deadline_miss_rate']:.3f} "
              f"(baseline {base.get('deadline_miss_rate', float('nan')):.3f})"
              f", p99 {cur['p99_ms']:.1f} ms "
              f"(baseline {base.get('p99_ms', float('nan')):.1f} ms)")
    if failures:
        print("\nREGRESSION:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print("serve bench within tolerance of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
