"""Composable LM substrate: dense GQA / MoE / SSM / RG-LRU / enc-dec / VLM."""
from .model import Model, build_model, make_batch_specs
from .sharding import param_specs, cache_specs, batch_axes

__all__ = ["Model", "build_model", "make_batch_specs", "param_specs",
           "cache_specs", "batch_axes"]
