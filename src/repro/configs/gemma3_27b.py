"""gemma3-27b — 5:1 local:global attention, 128k ctx
[hf:google/gemma-3-1b-pt family; unverified].
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144, head_dim=128."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-27b",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144,
    layer_pattern=("attn_local",) * 5 + ("attn_global",),
    window=1024, rope_theta=1_000_000.0,
    source="hf:google/gemma-3 (unverified); single rope_theta simplification",
)
