"""Elastic resize: reshard a checkpoint across different device layouts
(subprocess with 8 host devices: save sharded on 8, restore on 4+others)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.train import save_pytree, load_pytree, reshard_state

mesh8 = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
mesh4 = jax.make_mesh((4, 2), ("data", "model"),
                      axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.default_rng(0)
state = {"w": jnp.asarray(rng.standard_normal((16, 32)), jnp.float32),
         "b": jnp.asarray(rng.standard_normal((32,)), jnp.float32)}
spec8 = {"w": P("data", None), "b": P()}
sharded = jax.tree.map(lambda x, s: jax.device_put(x, NamedSharding(mesh8, s)),
                       state, spec8)
with tempfile.TemporaryDirectory() as d:
    save_pytree(sharded, d, 7)
    # restore onto a DIFFERENT mesh (8x1 -> 4x2) with different specs
    spec42 = {"w": P("data", "model"), "b": P("model")}
    shard42 = jax.tree.map(lambda s: NamedSharding(mesh4, s), spec42)
    restored, step = load_pytree(state, d, shardings=shard42)
    ok_step = step == 7
    maxdiff = max(float(jnp.abs(restored[k] - state[k]).max()) for k in state)
    # reshard in place too
    back = reshard_state(restored, mesh8, spec8)
    maxdiff2 = max(float(jnp.abs(back[k] - state[k]).max()) for k in state)
print("RESULT:" + json.dumps({"ok_step": ok_step, "maxdiff": maxdiff,
                              "maxdiff2": maxdiff2}))
"""


@pytest.mark.slow
def test_checkpoint_reshard_across_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["ok_step"]
    assert out["maxdiff"] == 0.0
    assert out["maxdiff2"] == 0.0
