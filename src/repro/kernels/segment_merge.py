"""Fused sorted-segment-merge Pallas kernel — ``sv_merge_add``'s hot loop.

The sparse backend's merge-add (sparsevec.py) is the paper's batched hash
insert: concat → sort → sum-adjacent-duplicates → compact.  The sort is an
XLA native (TPU sort is fast); everything *after* the sort is a chain of four
XLA ops (compare-shift, cumsum-group, segment_sum scatter, compaction
scatter) that each round-trips HBM.  This kernel fuses the O(N) post-sort
reduction into one pass over the sorted stream:

  * the stream is processed in VMEM blocks of ``BLK`` elements by an
    in-kernel ``fori_loop`` (one ``pallas_call`` program — vmap-safe: a
    batched call gives every lane its own loop and carries);
  * per block, run lengths become a local segment id by a cumsum over
    boundary flags, and the per-segment totals are computed with a one-hot
    contraction on the MXU — ``vals[1, BLK+1] @ onehot[BLK+1, BLK+1]`` —
    exactly the associativity trick of ``scatter_accum.py``;
  * segments spanning block boundaries are stitched by a carried scalar:
    the open segment's running sum is *prepended* to the next block's
    contraction operand, so every run is reduced as the left fold
    ``((v_1 + v_2) + v_3) + …`` in stream order — the same combine order as
    XLA's ``segment_sum`` scatter, which is what makes the ``pallas`` and
    ``xla`` op backends bit-identical (validated in interpret mode; on real
    MXUs the contraction order is the hardware's);
  * a second one-hot contraction places each run's total at its *last*
    stream position, and a carried int cumsum assigns each kept run its
    compacted output slot.

The wrapper :func:`segment_merge_sorted` owns the layout work (boundary
flags, padding, final compaction scatter) so callers deal in sorted-stream
terms; :mod:`repro.core.ops` routes ``SparseVec`` merges here under
``backend="pallas"``.

VMEM note: the whole stream lives in VMEM for the duration of the program
(~16 B/element across the five refs), so streams up to ~10⁶ elements fit
comfortably; the capacity-ladder extremes (cap_e ≳ 2²²) should stay on the
``xla`` backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_merge_stream", "segment_merge_sorted", "BLK"]

BLK = 256  # stream elements per fori_loop step (one-hot tiles are BLK+1 wide)


def _merge_kernel(first_ref, last_ref, sel_ref, vals_ref, tot_ref, rank_ref):
    """One program: left-fold run totals + compaction ranks over the stream.

    Inputs (all length ``nb·BLK``):
      first_ref: int32 — 1 where a run starts (ids[j] != ids[j-1])
      last_ref:  int32 — 1 where a run ends   (ids[j] != ids[j+1])
      sel_ref:   int32 — 1 at run ends of runs that are kept (id < sentinel)
    Outputs:
      tot_ref:  f32   — run total at each run's last position, 0 elsewhere
      rank_ref: int32 — inclusive count of kept runs up to each position
    """
    nb = first_ref.shape[0] // BLK
    col = jax.lax.broadcasted_iota(jnp.int32, (BLK + 1, BLK + 1), 1)
    pick_row = jax.lax.broadcasted_iota(jnp.int32, (BLK + 1, BLK), 0)
    col1 = jax.lax.broadcasted_iota(jnp.int32, (1, BLK + 1), 1)

    def body(i, carry):
        open_sum, rank0 = carry
        off = i * BLK
        first = first_ref[pl.ds(off, BLK)]
        last = last_ref[pl.ds(off, BLK)]
        sel = sel_ref[pl.ds(off, BLK)]
        vals = vals_ref[pl.ds(off, BLK)]

        # local segment id: 0 = segment carried open from the previous block
        g = jnp.cumsum(first)
        # prepend the carried running sum so block-spanning runs reduce as
        # one left fold in stream order (bit-identical to segment_sum)
        vext = jnp.concatenate([open_sum.reshape(1), vals])
        gext = jnp.concatenate([jnp.zeros((1,), jnp.int32), g])
        seg_oh = (col == gext[:, None]).astype(jnp.float32)
        part = jax.lax.dot_general(
            vext.reshape(1, BLK + 1), seg_oh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # [1, BLK+1] run sums

        # place each run's total at its last position (exact: one-hot pick)
        pick_oh = ((pick_row == g[None, :]) & (last[None, :] == 1)
                   ).astype(jnp.float32)
        totals = jax.lax.dot_general(
            part, pick_oh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).reshape(BLK)

        rank = rank0 + jnp.cumsum(sel)
        tot_ref[pl.ds(off, BLK)] = totals
        rank_ref[pl.ds(off, BLK)] = rank

        open_next = jnp.sum(jnp.where(col1 == g[BLK - 1], part, 0.0))
        open_next = jnp.where(last[BLK - 1] == 1, 0.0, open_next)
        return open_next.astype(jnp.float32), rank[BLK - 1]

    jax.lax.fori_loop(0, nb, body,
                      (jnp.float32(0.0), jnp.asarray(0, jnp.int32)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def segment_merge_stream(first, last, sel, vals, interpret: bool = False):
    """Run the fused kernel over a boundary-flagged sorted stream.

    All inputs are length ``tot`` (a multiple of :data:`BLK`); returns
    ``(totals f32[tot], rank int32[tot])`` as documented on the kernel.
    """
    tot = vals.shape[0]
    assert tot % BLK == 0, f"pad the stream to a multiple of {BLK}"
    return pl.pallas_call(
        _merge_kernel,
        out_shape=(jax.ShapeDtypeStruct((tot,), jnp.float32),
                   jax.ShapeDtypeStruct((tot,), jnp.int32)),
        interpret=interpret,
    )(first, last, sel, vals)


@functools.partial(jax.jit, static_argnames=("n", "cap", "interpret"))
def segment_merge_sorted(ids_s, vals_s, n: int, cap: int,
                         interpret: bool = False):
    """Sum duplicate runs of a *sorted* id stream and compact to ``cap``.

    Args:
      ids_s:  int32[tot] sorted ascending; entries ≥ ``n`` are sentinels.
      vals_s: f32[tot] values aligned with ``ids_s``.
      n:      sentinel threshold (one past the last valid id).
      cap:    output capacity.
    Returns:
      ``(out_ids int32[cap], out_vals f32[cap], count int32)`` — unique ids
      sorted ascending with per-id totals, sentinel-``n``/zero padded;
      ``count`` is the *uncapped* number of unique ids (callers compare it
      with ``cap`` for overflow).  Identical output contract (and, per run,
      identical f32 fold order) to the ``xla`` merge in
      :func:`repro.core.ops.segment_merge`.
    """
    tot = ids_s.shape[0]
    pad = (-tot) % BLK
    ids_p = jnp.concatenate([ids_s.astype(jnp.int32),
                             jnp.full((pad,), n, jnp.int32)])
    vals_p = jnp.concatenate([vals_s.astype(jnp.float32),
                              jnp.zeros((pad,), jnp.float32)])
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), ids_p[:-1]])
    nxt = jnp.concatenate([ids_p[1:], jnp.full((1,), -2, jnp.int32)])
    first = (ids_p != prev).astype(jnp.int32)
    last = (ids_p != nxt).astype(jnp.int32)
    keep = (last == 1) & (ids_p < n)
    totals, rank = segment_merge_stream(first, last,
                                        keep.astype(jnp.int32), vals_p,
                                        interpret=interpret)
    count = rank[-1]
    pos = rank - 1
    out_ids = jnp.full((cap,), n, jnp.int32).at[
        jnp.where(keep, pos, cap)].set(ids_p, mode="drop")
    out_vals = jnp.zeros((cap,), jnp.float32).at[
        jnp.where(keep, pos, cap)].set(totals, mode="drop")
    return out_ids, out_vals, count
