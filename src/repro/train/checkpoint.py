"""Checkpoint / restart / reshard — the fault-tolerance substrate.

Layout (one directory per step):

    ckpt_dir/step_000123/
        shard_00000.npz     # flattened leaves (this host's addressable data)
        MANIFEST.json       # tree structure, shapes, dtypes, mesh, step
    ckpt_dir/step_000123.COMMITTED   # atomic commit marker

Guarantees:
  * atomic commit — a crash mid-write never corrupts the latest checkpoint
    (restore scans for the newest COMMITTED marker);
  * async save — `save(..., blocking=False)` snapshots to host memory and
    writes on a background thread (training continues);
  * **reshard restore** — the manifest stores only global arrays, so a
    checkpoint written on one mesh loads onto any other (elastic resize,
    node-failure mesh shrink); `restore` takes target shardings.

Multi-host note: each process writes its addressable shards under its own
process index; this container is single-process, so shard_00000 carries the
full global array (jax.device_get of a sharded array materializes the global
value) — the format and commit protocol are the multi-host ones.
"""
from __future__ import annotations

import json
import os
import queue
import re
import threading
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["Checkpointer", "save_pytree", "load_pytree"]

_SEP = "::"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save_pytree(tree, directory: str, step: int) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".TMP")
    final = os.path.join(directory, name)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "shard_00000.npz"), **leaves)
    manifest = {
        "step": step,
        "keys": sorted(leaves.keys()),
        "shapes": {k: list(v.shape) for k, v in leaves.items()},
        "dtypes": {k: str(v.dtype) for k, v in leaves.items()},
        "format": 1,
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    # commit marker is the atomicity point
    with open(final + ".COMMITTED", "w") as f:
        f.write(name)
    return final


def load_pytree(template, directory: str, step: Optional[int] = None,
                shardings=None):
    """Restore into the structure of ``template``; optionally device_put with
    target shardings (reshard restore)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "shard_00000.npz"))
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat[0]:
        key = _SEP.join(str(getattr(q, "key", getattr(q, "idx", q)))
                        for q in p)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for f in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.COMMITTED", f)
        if m and os.path.isdir(os.path.join(directory, f[: -len(".COMMITTED")])):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class Checkpointer:
    """Async checkpointer with bounded queue + keep-last-k retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree, step = item
            try:
                save_pytree(tree, self.directory, step)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for f in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)\.COMMITTED", f)))
        import shutil
        for s in steps[: -self.keep]:
            name = os.path.join(self.directory, f"step_{s:08d}")
            if os.path.exists(name + ".COMMITTED"):
                os.remove(name + ".COMMITTED")
            if os.path.isdir(name):
                shutil.rmtree(name)

    def save(self, tree, step: int, blocking: bool = False):
        # snapshot to host memory NOW so training can mutate device buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((host_tree, step))
        if blocking:
            self.wait()

    def wait(self):
        self._q.join()
        if self._errors:
            raise self._errors.pop()

    def restore_latest(self, template, shardings=None):
        return load_pytree(template, self.directory, None, shardings)

    def close(self):
        self._q.put(None)
