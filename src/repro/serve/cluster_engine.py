"""Local clustering as a service: continuous batching over seed queries.

``LocalClusterEngine`` is the graph-query analogue of ``engine.py``'s
``batched_serve``: a queue of :class:`ClusterRequest`\\ s (seed, α, ε, method)
is packed into a fixed number of batch *lanes*; every scheduler tick advances
all active lanes a bounded number of push rounds through one jitted kernel,
finished lanes are harvested (swept for their best cut) and immediately
refilled from the queue — *without recompiling*, because lane count and
frontier capacities are static shapes and refill is a dynamic-index
injection into the batched state.

Requests with heterogeneous (α, ε) share one lane pool; only genuinely
trace-level choices (method, update rule, β, HK's (N, t)), the lane
*backend* (dense vs sparse state), and the capacity *bucket* select a pool.
Lanes that overflow their bucket's workspace are re-enqueued one
power-of-two bucket up (the bucketed recompilation contract of
core/frontier.py), so a request stream compiles at most O(log) distinct
shapes per (method, backend).  Idle pools beyond ``lru_pools`` are evicted
least-recently-used to bound device memory; the engine's
:class:`~repro.serve.aot.ExecutableCache` keeps the AOT-compiled tick
programs, so re-creating an evicted pool never re-traces.

Hot path
--------
Local (dense/sparse) pools run entirely through ahead-of-time-compiled
executables (serve/aot.py): every tick entry point — init, inject, step,
status, harvest-gather sweep — is ``jit(...).lower(...).compile()``'d once
per pool key (eagerly via :meth:`LocalClusterEngine.warmup`, else at first
pool creation), with the lane state **donated** on inject/step so pool
buffers update in place.  A tick pays exactly **one** device→host sync: the
stacked int32[6, B] status readback (finished / overflow / frontier / iters
/ pushes / exchanged), mirrored host-side and consumed by harvest, the
finalize counters, the scheduler's pending-rounds hints, and trace
annotations alike.  Harvest copies a finished lane's *support* (order
buffer + 4 counters + φ), never pool state.  In front of it all sits a
versioned seed→result LRU (serve/result_cache.py): a repeated query resolves
at submit in O(1), keyed on the handle's graph version so edge mutations
invalidate wholesale.  None of this changes answers — AOT lowering,
donation, coalesced readbacks, and caching move bytes and compile time,
never values (docs/algorithms.md, guarantee #9).

Backends
--------
``backend="dense"`` lanes carry f32[n] state vectors (fast lookups, memory
O(n) per lane).  ``backend="sparse"`` lanes carry :class:`SparseVec`
``(ids, vals)`` pairs of capacity ``cap_v`` — per-lane live state O(cap_v),
independent of n — and are harvested with the sparse sweep
(:func:`repro.core.sweep.sweep_cut_sparse`), so a sparse request never
materializes a dense vector anywhere on its path.  ``backend="dist"`` lanes (available when the
engine's :class:`~repro.graphs.handle.GraphHandle` is sharded) carry their
state *sharded over the mesh's data axis* — [B, n/D] per chip — and step
through the shard_map'd round kernels of :mod:`repro.core.batched_dist`
(one bucketed all_to_all per round for the whole pool); dist pools are keyed
on the shard topology (axis, D), so two meshes never share a compiled shape.
``backend="auto"`` (default) picks per request via
:func:`repro.core.batched_sparse.pick_backend` (sparse iff n ≥ 2·ratio·cap_v;
dist iff the graph is sharded and the dense lane state would blow
``dist_chip_budget``); a request can pin its lane type with
``ClusterRequest.backend``.  The sparse and dist states exist only for plain
PR-Nibble (β = 1): HK-PR or β-selection requests always serve dense.

Orthogonal to the lane type is the *kernel* backend
(``ops_backend="xla" | "pallas" | "auto"``, engine-wide or per request via
``ClusterRequest.ops_backend``): which implementation every scatter/merge/
scan inside the rounds dispatches to (:mod:`repro.core.ops`).  Results are
bit-identical across kernel backends, so the scheduler may serve a mixed
stream from differently-backed pools without changing any answer.

Scheduling surface
------------------
The engine itself is a *drain-oriented* batcher; the asynchronous,
deadline-aware layer lives above it in serve/scheduler.py
(``AsyncClusterEngine``).  What this module exposes for that layer:
per-pool stepping (:meth:`LocalClusterEngine.tick_pool` — one refill →
step → harvest pass of a single pool, wall-time measured and folded into
the pool's ``cost_ema``), pool observables (``occupancy``, ``tickets``,
``pending_rounds``/``pending_ticks`` built on the batched layers'
rounds-remaining hints), partial harvest for deadline expiry
(:meth:`LocalClusterEngine.harvest_partial` → ``deadline_missed=True``
results), and batch result pickup (:meth:`LocalClusterEngine.take_completed`).
Scheduling never changes answers: any interleaving of ``tick_pool`` calls
steps each lane through the same round function in the same order, so a
scheduled request's result is bit-identical to ``run()``'s.

Capacity-ladder / retry contract: buckets follow the single-seed drivers'
doubling schedule (cap_f, cap_v clamped at n+1; cap_e unclamped to
``max_cap_e``; sweep caps likewise), so a request promoted b buckets up
computes bit-identically to the single-seed driver retrying b times.
Recompile boundary: (method, backend, statics, ops_backend, bucket, topo) ×
batch_slots — ``topo`` is the shard topology (mesh axis, shard count) for
dist pools, None for local ones; all dynamic knobs (seed, α, ε, lane
occupancy) move through traced values.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from repro.graphs.handle import GraphHandle, as_handle
from repro.core import ops as core_ops
from repro.core.batched_dist import dist_lane_kernels
from repro.core.pr_nibble import MAX_ITERS
from repro.core.sweep import sweep_cut_dense, sweep_cut_sparse
from repro.core.batched import (STATUS_EXCHANGED, STATUS_FINISHED,
                                STATUS_FRONTIER, STATUS_ITER, STATUS_OVERFLOW,
                                STATUS_PUSHES, dense_lane_kernels,
                                hk_rounds_remaining, rounds_remaining_hint)
from repro.core.batched_sparse import pick_backend, sparse_lane_kernels
from repro.serve.aot import ExecutableCache, compile_lane_executables
from repro.serve.result_cache import ResultCache, result_key
from repro.serve.telemetry import EMA, pool_label
from repro.serve.tracing import RequestTrace, Tracer

__all__ = ["ClusterRequest", "ClusterResult", "LocalClusterEngine",
           "UnknownTicket"]


class UnknownTicket(KeyError):
    """Raised by :meth:`LocalClusterEngine.result` / :meth:`peek` for a
    ticket this engine never issued, or whose result was already consumed."""


@dataclasses.dataclass(frozen=True)
class ClusterRequest:
    """One local-clustering query: which seed, which diffusion, which knobs."""
    seed: int
    alpha: float = 0.01        # PR-Nibble teleport
    eps: float = 1e-6          # approximation / truncation threshold
    method: str = "pr_nibble"  # "pr_nibble" | "hk_pr"
    optimized: bool = True     # PR-Nibble update rule (Fig 3 vs Fig 4)
    beta: float = 1.0          # PR-Nibble top-β round selection
    N: int = 10                # HK-PR Taylor degree
    t: float = 5.0             # HK-PR temperature
    backend: Optional[str] = None  # None = engine default; "dense" | "sparse"
    ops_backend: Optional[str] = None  # None = engine default; "xla" |
    #   "pallas" | "auto" — kernel backend (repro.core.ops), orthogonal to
    #   the dense/sparse lane choice; results are bit-identical across it
    # Scheduling hints, consumed by serve/scheduler.py's AsyncClusterEngine
    # (the synchronous engine ignores them).  Never part of a pool key:
    # deadlines/priorities order work, they never select a compiled program.
    deadline_ms: Optional[float] = None  # latency budget from submission;
    #   None = best effort (no deadline)
    priority: int = 0          # higher = more urgent among undeadlined work


@dataclasses.dataclass
class ClusterResult:
    request: ClusterRequest
    conductance: float         # φ of the best sweep prefix
    size: int                  # |S*|
    volume: int                # vol(S*)
    support: int               # nnz of the diffusion vector
    cluster: np.ndarray        # int32[size] — member vertex ids
    pushes: int
    iterations: int
    bucket: int                # capacity bucket that served the request
    overflow: bool             # True only if every bucket overflowed
    backend: str = "dense"     # lane type that served the request
    ops_backend: str = "xla"   # kernel backend that served the request
    deadline_missed: bool = False  # True: the deadline expired and this is a
    #   best-effort partial harvest (or a completed-but-late delivery), not
    #   the converged diffusion


# --------------------------------------------------------------- tick kernels
# Local (dense/sparse) pools step through AOT-compiled executables: the
# LaneKernels factories of core/batched.py / core/batched_sparse.py are
# lowered+compiled per pool key by the engine's ExecutableCache
# (serve/aot.py), with the lane state donated — see LocalClusterEngine.
# Dist pools keep their shard_map'd jits (repro.core.batched_dist, lru_cached
# per topology); only their coalesced status readback lives here.

@jax.jit
def _dist_status(front, t, pushes, overflow, exchanged):
    """Stacked int32[6, B] status readback for dist lanes — the replicated
    per-lane scalars of DistLaneState, in the STATUS_* row order of
    repro.core.batched, so one transfer serves harvest, the scheduler's
    pending-rounds hints, and the trace annotations."""
    i32 = lambda x: x.astype(jnp.int32)
    fin = (front == 0) | overflow | (t >= MAX_ITERS)
    return jnp.stack([i32(fin), i32(overflow), i32(front), i32(t),
                      i32(pushes), i32(exchanged)])


# ----------------------------------------------------------------- lane pool

class _Pool:
    """Fixed-shape lane pool for one (method, backend, statics, ops_backend,
    bucket, topo) key.  ``topo`` is None for local (dense/sparse) pools and
    the (mesh axis, shard count) pair for ``dist`` pools — shard topology is
    pool-key material because it selects a different compiled SPMD program."""

    def __init__(self, engine: "LocalClusterEngine", key: tuple):
        method, backend, statics, ops_backend, bucket, topo = key
        self.engine = engine
        self.key = key
        self.method = method
        self.backend = backend
        self.ops_backend = ops_backend
        self.statics = statics
        self.bucket = bucket
        self.topo = topo
        caps = engine._pool_caps(key)
        self.cap_f = caps["cap_f"]
        self.cap_e = caps["cap_e"]
        self.cap_n = caps["cap_n"]
        self.sweep_cap_e = caps["sweep_cap_e"]
        self.cap_v = caps["cap_v"]
        B = engine.batch_slots
        # lanes start inactive; injected states overwrite these placeholders
        if backend == "dist":
            pg = engine.handle.partitioned()
            mesh = engine.handle.require_mesh()
            self.cap_x = caps["cap_x"]
            optimized, _beta = statics
            self._dist_init, self._dist_inject, self._dist_step_for = \
                dist_lane_kernels(mesh, engine.handle.axis, pg.rows_per,
                                  self.cap_f, self.cap_e, self.cap_x,
                                  optimized, ops_backend)
            self.exec = None    # dist pools step through the shard_map jits
            self.state = self._dist_init(jnp.zeros((B,), jnp.int32))
        else:
            # AOT executables from the engine's cache: a re-created pool
            # (after LRU eviction) or a ladder hop re-uses the compiled
            # programs — pool construction never re-traces after warmup
            self.exec = engine._executables_for(key)
            self.state = self.exec.init(jnp.zeros((B,), jnp.int32))
        self.eps = np.zeros(B, np.float32)
        self.alpha = np.zeros(B, np.float32)
        self.lane: List[Optional[Tuple[int, ClusterRequest]]] = [None] * B
        self.queue: deque = deque()
        # Host mirror of the tick's coalesced status readback
        # (int32[STATUS_ROWS, B]): written once per tick by harvest's single
        # device→host sync, patched host-side on inject, consumed by
        # finalize (pushes/iterations/overflow) and the scheduler hints
        # (pending_rounds) — nothing else re-syncs.
        self._status_host: Optional[np.ndarray] = None
        # Cost-model observables (serve/scheduler.py): EMA of measured tick
        # wall time, fed by LocalClusterEngine.tick_pool.  None until the
        # first tick.  Same telemetry.EMA the registry exports, so alpha is
        # configured in exactly one place (engine.cost_ema_alpha).
        self._cost = EMA(engine.cost_ema_alpha)
        self.ticks = 0
        engine.stats["pools_created"] += 1
        engine.stats["bucket_shapes"].add(
            (method, backend, ops_backend, B, self.cap_f, self.cap_e, topo))

    def has_work(self) -> bool:
        return bool(self.queue) or any(l is not None for l in self.lane)

    # -- scheduler observables ----------------------------------------------

    def note_tick(self, seconds: float) -> None:
        """Fold one measured refill+step+harvest wall time into the EMA."""
        self.ticks += 1
        self._cost.update(seconds)

    @property
    def cost_ema(self) -> Optional[float]:
        """EMA of measured tick wall time (None before the first tick)."""
        return self._cost.value

    def occupancy(self) -> int:
        """Active lanes (injected, not yet harvested)."""
        return sum(l is not None for l in self.lane)

    def tickets(self) -> List[int]:
        """Every ticket resident in this pool: active lanes, then queued."""
        out = [slot[0] for slot in self.lane if slot is not None]
        out.extend(idx for idx, _ in self.queue)
        return out

    def pending_rounds(self) -> np.ndarray:
        """Estimated push rounds remaining per active lane (0 for idle
        lanes).  PR-Nibble lanes (dense, sparse, or dist — same round
        structure) use the survival hint
        :func:`repro.core.batched.rounds_remaining_hint`; HK-PR lanes know
        their remaining Taylor levels exactly
        (:func:`repro.core.batched.hk_rounds_remaining`).  Free of device
        syncs: consumes the host mirror of the tick's coalesced status
        readback.  For a pool that has never pulled status, every occupied
        lane is freshly injected (t = 0, singleton frontier), for which the
        survival hint is exactly 1 round — synthesized host-side."""
        mask = np.array([l is not None for l in self.lane])
        sh = self._status_host
        if sh is None:
            return np.where(mask, 1, 0)
        iters, fc = sh[STATUS_ITER], sh[STATUS_FRONTIER]
        if self.method == "pr_nibble":
            hints = rounds_remaining_hint(iters, fc)
        else:
            N, _ = self.statics
            hints = hk_rounds_remaining(
                iters, sh[STATUS_FINISHED].astype(bool), fc, N)
        return np.where(mask, hints, 0)

    def pending_ticks(self) -> int:
        """Estimated scheduler ticks until this pool drains: the slowest
        active lane's rounds / rounds_per_step, plus one such stretch per
        refill wave the queue implies.  Crude by design — the scheduler
        multiplies it by the tick-cost EMA to rank pools, nothing else."""
        if not self.has_work():
            return 0
        r = max(self.engine.rounds_per_step, 1)
        hints = self.pending_rounds()
        lane_part = int(math.ceil(int(hints.max()) / r)) if hints.size else 0
        waves = math.ceil(len(self.queue) / max(len(self.lane), 1))
        return max(lane_part + waves * max(lane_part, 1), 1)

    def refill(self) -> None:
        for i in range(len(self.lane)):
            if self.lane[i] is not None or not self.queue:
                continue
            idx, req = self.queue.popleft()
            self.lane[i] = (idx, req)
            self.eps[i] = req.eps
            self.alpha[i] = req.alpha
            lane = jnp.asarray(i, jnp.int32)
            seed = jnp.asarray(req.seed, jnp.int32)
            if self.backend == "dist":
                self.state = self._dist_inject(self.state, lane, seed)
            else:
                # donated: the old state buffers are consumed in place
                self.state = self.exec.inject(self.state, lane, seed)
            if self._status_host is not None:
                # keep the host status mirror truthful for lanes injected
                # after the last pull: a fresh lane is exactly (unfinished,
                # no overflow, singleton frontier, 0 iters, 0 pushes) — so
                # a force-finalize or scheduler hint between now and the
                # next harvest reads correct values without a sync
                self._status_host[:, i] = (0, 0, 1, 0, 0, 0)
            self.engine.stats["injections"] += 1
            rt = self.engine._rt.get(idx)
            if rt is not None:
                rt.phase("resident", lane=i, bucket=self.bucket)
                rt.event("injected", lane=i, seed=req.seed)

    def step(self) -> None:
        active = np.array([l is not None for l in self.lane])
        if not active.any():
            return
        if self.backend == "dist":
            pg = self.engine.handle.partitioned()
            self.state = self._dist_step_for(self.engine.rounds_per_step)(
                pg.indptr, pg.indices, pg.deg, self.state,
                jnp.asarray(self.eps), jnp.asarray(self.alpha),
                jnp.asarray(active))
        else:
            # AOT executable, state donated: no jit-cache lookup, no trace,
            # and the pool buffers update in place
            self.state = self.exec.step(
                self.engine.graph, self.state, jnp.asarray(self.eps),
                jnp.asarray(self.alpha), jnp.asarray(active))
        self.engine.stats["steps"] += 1

    def _pull_status(self) -> np.ndarray:
        """The tick's ONE device→host sync: the stacked int32[6, B] status
        readback (finished/overflow/frontier/iters/pushes/exchanged), cached
        on the pool for everything downstream — harvest decisions, finalize
        counters, scheduler hints, trace annotations."""
        st = self.state
        if self.backend == "dist":
            dev = _dist_status(st.front, st.t, st.pushes, st.overflow,
                               st.exchanged)
        else:
            dev = self.exec.status(st)
        # np.array (not asarray): the mirror must be writable — refill
        # patches freshly injected lanes' rows host-side between pulls
        self._status_host = np.array(dev)
        self.engine.stats["status_syncs"] += 1
        return self._status_host

    def _ensure_status(self) -> np.ndarray:
        """The host status mirror, pulling it only if this pool has never
        synced (possible for force-finalize before any tick)."""
        if self._status_host is None:
            return self._pull_status()
        return self._status_host

    def harvest(self) -> None:
        if not any(l is not None for l in self.lane):
            return
        sh = self._pull_status()
        finished = sh[STATUS_FINISHED].astype(bool)
        ovf = sh[STATUS_OVERFLOW].astype(bool)
        count = sh[STATUS_FRONTIER]
        # Per-lane request annotations (traced runs only): every observable
        # rides the coalesced readback — tracing costs no extra sync.
        if self.engine.tracer is not None:
            for i, slot in enumerate(self.lane):
                if slot is None:
                    continue
                rt = self.engine._rt.get(slot[0])
                if rt is not None:
                    obs = dict(frontier=int(count[i]),
                               pushes=int(sh[STATUS_PUSHES][i]),
                               overflow=bool(ovf[i]),
                               finished=bool(finished[i]))
                    if self.backend == "dist":
                        obs["exchanged"] = int(sh[STATUS_EXCHANGED][i])
                    rt.event("lane_obs", **obs)
        for i, slot in enumerate(self.lane):
            if slot is None or not finished[i]:
                continue
            idx, req = slot
            self.lane[i] = None
            rt = self.engine._rt.get(idx)
            if ovf[i] and self.engine._promote(idx, req, self.bucket):
                if rt is not None:
                    rt.event("promoted", from_bucket=self.bucket,
                             to_bucket=self.bucket + 1)
                continue
            if rt is not None:
                rt.event("harvest", frontier=int(count[i]),
                         overflow=bool(ovf[i]))
                rt.phase("sweep", bucket=self.bucket)
            self.engine._complete(idx, self._finalize(i, req, bool(ovf[i])))

    def force_finalize(self, i: int) -> ClusterResult:
        """Harvest lane ``i`` *now*, finished or not: sweep whatever
        diffusion mass the lane has accumulated so far and free the slot.
        The deadline scheduler uses this to turn an expired request into a
        best-effort partial result instead of letting it finish late."""
        idx, req = self.lane[i]
        self.lane[i] = None
        ovf = bool(self._ensure_status()[STATUS_OVERFLOW][i])
        rt = self.engine._rt.get(idx)
        if rt is not None:
            rt.event("expired", lane=i, bucket=self.bucket)
            rt.phase("sweep", bucket=self.bucket, partial=True)
        return self._finalize(i, req, ovf)

    def _finalize(self, i: int, req: ClusterRequest,
                  overflowed: bool) -> ClusterResult:
        eng = self.engine
        n = eng.graph.n
        cap_n, cap_se = self.cap_n, self.sweep_cap_e
        max_cap_se = eng.sweep_cap_e << eng.max_bucket
        sh = self._ensure_status()
        size = None
        if self.exec is not None:
            # Harvest-gather executable: slice the one finished lane's
            # support out of the pool and sweep it on-device — only the
            # order buffer, 4 counters, and φ cross to the host, never the
            # pool state.
            order, meta, phi = self.exec.sweep(eng.graph, self.state,
                                               jnp.asarray(i, jnp.int32))
            meta = np.asarray(meta)   # [best_size, best_volume, nnz, ovf]
            sweep_ovf = bool(meta[3])
            exhausted = (cap_se >= max_cap_se
                         and (self.backend == "sparse" or cap_n >= n))
            if not sweep_ovf or exhausted:
                size = int(meta[0])
                conductance = float(np.asarray(phi))
                volume, support = int(meta[1]), int(meta[2])
                members = np.asarray(order)[:size].astype(np.int32)
                overflowed = overflowed or sweep_ovf
        if size is None:
            # Sweep workspace too small at pool caps (rare), or a dist lane
            # (no local sweep executable): sweep through the jit path on
            # the capacity ladder — the diffusion state is still resident,
            # so this costs a sweep, never a re-run, and each shape
            # compiles once.
            if self.exec is not None:   # pool caps already tried above
                cap_n = min(cap_n * 2, n)
                cap_se = min(cap_se * 2, max_cap_se)
            if self.backend == "sparse":
                # sparse lanes sweep their own support — the grid is cap_v,
                # so only the sweep edge workspace can need a retry
                p_sv = jax.tree.map(lambda buf: buf[i], self.state.p)
                while True:
                    sw = sweep_cut_sparse(eng.graph, p_sv.ids, p_sv.vals,
                                          p_sv.count, cap_se,
                                          backend=self.ops_backend)
                    if not bool(sw.overflow) or cap_se >= max_cap_se:
                        break
                    cap_se = min(cap_se * 2, max_cap_se)
            else:
                # dist lanes sweep on the handle's local CSR: the sharded p
                # row is sliced back to the true vertex count (sentinel
                # padding can never enter the sweep), and — the rows being
                # bit-identical to a dense lane's — the sweep result is too
                p_i = (self.state.p[i][: n] if self.backend == "dist"
                       else self.state.p[i])
                while True:
                    sw = sweep_cut_dense(eng.graph, p_i, cap_n, cap_se,
                                         self.ops_backend)
                    if not bool(sw.overflow) or (cap_n >= n and
                                                 cap_se >= max_cap_se):
                        break
                    cap_n = min(cap_n * 2, n)
                    cap_se = min(cap_se * 2, max_cap_se)
            overflowed = overflowed or bool(sw.overflow)
            size = int(sw.best_size)
            conductance = float(sw.best_conductance)
            volume, support = int(sw.best_volume), int(sw.nnz)
            members = np.asarray(sw.order)[:size].astype(np.int32)
        return ClusterResult(
            request=req,
            conductance=conductance,
            size=size,
            volume=volume,
            support=support,
            cluster=members,
            pushes=int(sh[STATUS_PUSHES][i]),
            iterations=int(sh[STATUS_ITER][i]),
            bucket=self.bucket,
            overflow=overflowed,
            backend=self.backend,
            ops_backend=self.ops_backend,
        )


# -------------------------------------------------------------------- engine

class LocalClusterEngine:
    """Continuous-batching server for local clustering queries on one graph.

    >>> eng = LocalClusterEngine(graph, batch_slots=8)
    >>> results = eng.run([ClusterRequest(seed=s) for s in seeds])

    ``run`` preserves request order.  ``submit``/``poll``/``drain`` expose the
    incremental interface for callers interleaving their own work.
    """

    def __init__(self, graph, batch_slots: int = 8,
                 cap_f: int = 1 << 12, cap_e: int = 1 << 16,
                 cap_n: int = 1 << 11, sweep_cap_e: int = 1 << 17,
                 max_cap_e: int = 1 << 26, rounds_per_step: int = 16,
                 lru_pools: int = 4, cap_v: int = 1 << 12,
                 backend: str = "auto", sparse_ratio: int = 4,
                 ops_backend: str = "auto", cap_x: int = 1 << 12,
                 dist_chip_budget: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 cost_ema_alpha: float = 0.3,
                 result_cache=1024):
        """``graph`` is any graph-like — a resident ``CSRGraph`` or a
        :class:`~repro.graphs.handle.GraphHandle` (possibly sharded over a
        mesh, which unlocks the ``dist`` lane pools).

        ``backend`` is the engine-wide default lane type: "dense", "sparse",
        "dist" (sharded handles only), or "auto" (pick per request by
        :func:`repro.core.batched_sparse.pick_backend` with ``sparse_ratio``
        and — when the handle is sharded — the fits-on-chip rule against
        ``dist_chip_budget`` bytes of dense per-lane state).
        ``cap_v`` is the sparse lanes' value capacity K at bucket 0;
        ``cap_x`` is the dist lanes' per-owner exchange-bucket capacity at
        bucket 0.  ``ops_backend`` is the engine-wide default *kernel*
        backend ("xla" | "pallas" | "auto" → TPU? pallas : xla) — orthogonal
        to the lane type; requests may pin their own via
        ``ClusterRequest.ops_backend``.  Results are bit-identical across
        kernel backends *and* across lane backends for the dense/dist pair,
        so mixing them in one stream is safe.

        ``tracer`` (a :class:`repro.serve.tracing.Tracer`, default None =
        tracing off) records a span tree per request and per-tick pool
        spans; tracing only *observes* state the engine computed, so traced
        results are bit-identical to untraced ones (docs/algorithms.md,
        guarantee #8).  ``cost_ema_alpha`` is the smoothing factor of every
        pool's tick-cost EMA (the scheduler's cost model).

        ``result_cache`` is the versioned seed→result LRU
        (:mod:`repro.serve.result_cache`): an int is its entry capacity, a
        :class:`~repro.serve.result_cache.ResultCache` instance is shared
        as-is (several engines over one graph may pool their hits), and
        ``0``/``None`` disables caching.  A hit resolves at :meth:`submit`
        — no lane, no tick — and is bit-identical to recomputing
        (guarantee #9); bumping the handle's graph version invalidates
        every entry at once."""
        if backend not in ("auto", "dense", "sparse", "dist"):
            raise ValueError(f"unknown backend: {backend!r}")
        self.handle = as_handle(graph)
        if backend == "dist":
            if not self.handle.is_sharded:
                raise ValueError(
                    "backend='dist' needs a sharded GraphHandle "
                    "(GraphHandle.shard(csr, mesh))")
            self.handle.require_mesh()   # fail at construction, not submit
        self.ops_backend = core_ops.resolve(ops_backend)
        self.batch_slots = batch_slots
        self.cap_f = cap_f
        self.cap_e = cap_e
        self.cap_n = cap_n
        self.sweep_cap_e = sweep_cap_e
        self.cap_v = cap_v
        self.cap_x = cap_x
        self.backend = backend
        self.sparse_ratio = sparse_ratio
        self.dist_chip_budget = dist_chip_budget
        self.rounds_per_step = rounds_per_step
        self.lru_pools = lru_pools
        self.max_bucket = max(0, (max_cap_e // cap_e).bit_length() - 1)
        self.pools: "OrderedDict[tuple, _Pool]" = OrderedDict()
        # AOT executable cache: pool key → compiled tick programs.  Outlives
        # pool eviction by design — see serve/aot.py.
        self._exec_cache = ExecutableCache()
        if isinstance(result_cache, ResultCache):
            self.result_cache: Optional[ResultCache] = result_cache
        elif result_cache:
            self.result_cache = ResultCache(int(result_cache))
        else:
            self.result_cache = None
        self.stats: Dict = dict(steps=0, injections=0, promotions=0,
                                completed=0, pools_created=0,
                                pools_evicted=0, partial_harvests=0,
                                status_syncs=0, aot_compiles=0,
                                aot_cache_hits=0, aot_compile_s=0.0,
                                result_cache_hits=0, result_cache_misses=0,
                                bucket_shapes=set())
        self._results: Dict[int, ClusterResult] = {}
        self._next_idx = 0
        self.tracer = tracer
        self.cost_ema_alpha = cost_ema_alpha
        # ticket → RequestTrace for in-flight traced requests; traces are
        # finished and dropped at result pickup
        self._rt: Dict[int, RequestTrace] = {}

    @property
    def graph(self) -> CSRGraph:
        """The resident-CSR view (materialized from the partition slabs and
        cached when the engine was built sharded-first): what the local lane
        pools step against and every harvest sweeps with."""
        return self.handle.local()

    # -- AOT compile lifecycle ----------------------------------------------

    def _pool_caps(self, key: tuple) -> Dict[str, int]:
        """Workspace capacities of the pool at ``key``'s bucket — the
        doubling ladder of the single-seed drivers, clamped at the graph's
        natural sizes (and, for dist pools, at the shard's row count /
        the edge workspace).  Centralized so the pool construction and the
        AOT kernel builder can never disagree on a shape."""
        _method, backend, _statics, _ops, bucket, _topo = key
        n = self.handle.n
        caps = dict(cap_f=min(self.cap_f << bucket, n + 1),
                    cap_e=self.cap_e << bucket,
                    cap_n=min(self.cap_n << bucket, n),
                    sweep_cap_e=self.sweep_cap_e << bucket,
                    cap_v=min(self.cap_v << bucket, n + 1))
        if backend == "dist":
            pg = self.handle.partitioned()
            # dist cap_f is *per shard*: a local frontier can never exceed
            # the shard's row count
            caps["cap_f"] = min(self.cap_f << bucket, pg.rows_per + 1)
            caps["cap_x"] = min(self.cap_x << bucket, caps["cap_e"])
        return caps

    def _executables_for(self, key: tuple):
        """The AOT-compiled tick executables for pool ``key``, building
        (lower + compile against the pool's exact avals) at most once per
        key for the engine's lifetime.  Ladder promotion hops between
        already-compiled buckets; an LRU-evicted pool's re-creation is a
        cache hit, never a re-trace."""
        method, backend, statics, ops_backend, _bucket, _topo = key
        caps = self._pool_caps(key)
        n = self.handle.n

        def build():
            if backend == "sparse":
                kern = sparse_lane_kernels(
                    n, statics, caps["cap_f"], caps["cap_v"], caps["cap_e"],
                    caps["sweep_cap_e"], self.rounds_per_step, ops_backend)
            else:
                kern = dense_lane_kernels(
                    n, method, statics, caps["cap_f"], caps["cap_e"],
                    caps["cap_n"], caps["sweep_cap_e"],
                    self.rounds_per_step, ops_backend)
            return compile_lane_executables(kern, self.graph,
                                            self.batch_slots)

        ex = self._exec_cache.get(key, build)
        cs = self._exec_cache.stats()
        self.stats["aot_compiles"] = cs["compiles"]
        self.stats["aot_cache_hits"] = cs["hits"]
        self.stats["aot_compile_s"] = cs["compile_seconds"]
        return ex

    def warmup(self, requests: Optional[List[ClusterRequest]] = None,
               max_bucket: int = 1) -> Dict:
        """Eagerly AOT-compile the tick executables every request in
        ``requests`` would touch, over buckets ``0..max_bucket`` of the
        capacity ladder — so the serving steady state never pays a
        first-touch trace.  ``requests`` are *prototypes* (seed/α/ε don't
        matter — only the pool-key material: method, statics, resolved
        backends); default is one plain PR-Nibble prototype.  Dist pools
        keep the jit path (their shard_map programs warm on first tick) and
        are skipped.  Returns ``dict(seconds, compiled, buckets)``."""
        t0 = time.perf_counter()
        if requests is None:
            requests = [ClusterRequest(seed=0)]
        before = self._exec_cache.stats()["compiles"]
        hi = min(max_bucket, self.max_bucket)
        for req in requests:
            for b in range(hi + 1):
                key = self._pool_key(req, b)
                if key[1] == "dist":
                    continue
                self._executables_for(key)
        return dict(seconds=time.perf_counter() - t0,
                    compiled=self._exec_cache.stats()["compiles"] - before,
                    buckets=hi + 1)

    # -- result cache --------------------------------------------------------

    def cached_result(self, req: ClusterRequest) -> Optional[ClusterResult]:
        """The cached converged result for ``req`` at the current graph
        version, or None.  A hit is a fresh :class:`ClusterResult` copy
        carrying ``req`` itself — bit-identical cluster/φ to what a lane
        would compute (guarantee #9)."""
        if self.result_cache is None:
            return None
        key = result_key(req, self._resolve_backend(req),
                         self.handle.version)
        res = self.result_cache.get(key, request=req)
        self.stats["result_cache_hits"] = self.result_cache.hits
        self.stats["result_cache_misses"] = self.result_cache.misses
        return res

    # -- scheduling ----------------------------------------------------------

    def _resolve_backend(self, req: ClusterRequest) -> str:
        """Which lane type serves ``req``: its pin, else the engine default,
        with "auto" resolved by the graph-size/K (and, for sharded handles,
        fits-on-chip) heuristic.  Sparse and dist state exists only for plain
        PR-Nibble (β = 1): a *request-level* sparse/dist pin on an
        unsupported query is an error; an engine-level "sparse"/"dist"
        default or an "auto" resolution falls back to dense for those
        queries."""
        b = req.backend if req.backend is not None else self.backend
        if b not in ("auto", "dense", "sparse", "dist"):
            raise ValueError(f"unknown backend: {b!r}")
        if b == "dist":
            if not self.handle.is_sharded:
                raise ValueError("backend='dist' needs a sharded GraphHandle")
            # a sharded handle without a mesh can't run dist pools — raise
            # here (submit validates on the caller's thread) rather than
            # from _Pool.__init__ inside the scheduler's drive thread
            self.handle.require_mesh()
        lane_ok = req.method == "pr_nibble" and req.beta == 1.0
        if not lane_ok:
            if req.backend in ("sparse", "dist"):
                raise ValueError(
                    f"backend={req.backend!r} supports only pr_nibble with "
                    f"beta=1.0 (got method={req.method!r}, beta={req.beta})")
            return "dense"
        if b == "auto":
            # dist is only reachable for auto resolution when the handle can
            # actually run it (sharded AND carries a mesh) — a mesh-less
            # sharded handle falls back to the local heuristic instead of
            # exploding at submit time
            dist_ready = self.handle.is_sharded and self.handle.mesh is not None
            b = pick_backend(
                self.handle.n, self.cap_v, self.sparse_ratio,
                num_shards=self.handle.num_shards if dist_ready else 1,
                chip_budget=self.dist_chip_budget)
        return b

    def _resolve_ops_backend(self, req: ClusterRequest) -> str:
        """Kernel backend serving ``req``: its pin, else the engine default
        ("auto" resolved at engine construction)."""
        if req.ops_backend is None:
            return self.ops_backend
        return core_ops.resolve(req.ops_backend)

    def _pool_key(self, req: ClusterRequest, bucket: int) -> tuple:
        """(method, backend, statics, ops_backend, bucket, topo) — ``topo``
        is the shard topology (axis, D) for dist pools, None otherwise, so
        dist pools can never alias local pools (or each other across
        meshes) in the compile cache, the LRU, or the telemetry labels."""
        if req.method == "pr_nibble":
            statics = (req.optimized, req.beta)
        elif req.method == "hk_pr":
            statics = (req.N, req.t)
        else:
            raise ValueError(f"unknown method: {req.method!r}")
        backend = self._resolve_backend(req)
        topo = ((self.handle.axis, self.handle.num_shards)
                if backend == "dist" else None)
        return (req.method, backend, statics,
                self._resolve_ops_backend(req), bucket, topo)

    def _enqueue(self, idx: int, req: ClusterRequest, bucket: int) -> None:
        key = self._pool_key(req, bucket)
        pool = self.pools.get(key)
        if pool is None:
            pool = _Pool(self, key)
            self.pools[key] = pool
        self.pools.move_to_end(key)
        pool.queue.append((idx, req))   # before evict: a pool with work is safe
        rt = self._rt.get(idx)
        if rt is not None:
            rt.phase("pool_queue", pool=pool_label(key), bucket=bucket)
        self._evict_idle()

    def _promote(self, idx: int, req: ClusterRequest, bucket: int) -> bool:
        """Re-enqueue an overflowed request one bucket up.  Returns False if
        the capacity ladder is exhausted (caller reports overflow)."""
        if bucket + 1 > self.max_bucket:
            return False
        self.stats["promotions"] += 1
        self._enqueue(idx, req, bucket + 1)
        return True

    def _complete(self, idx: int, res: ClusterResult) -> None:
        self._results[idx] = res
        self.stats["completed"] += 1
        if self.result_cache is not None and not res.deadline_missed:
            self.result_cache.put(
                result_key(res.request, res.backend, self.handle.version),
                res)
        rt = self._rt.get(idx)
        if rt is not None:
            # inf conductance (empty partial harvest) is not valid JSON
            phi = res.conductance if math.isfinite(res.conductance) else None
            rt.phase("deliver", conductance=phi, size=res.size,
                     pushes=res.pushes)

    def _evict_idle(self) -> None:
        while len(self.pools) > self.lru_pools:
            victim = next((k for k, p in self.pools.items()
                           if not p.has_work()), None)
            if victim is None:
                break
            del self.pools[victim]
            self.stats["pools_evicted"] += 1

    # -- public API ----------------------------------------------------------

    def submit(self, req: ClusterRequest,
               _trace: Optional[RequestTrace] = None,
               _skip_cache: bool = False) -> int:
        """Queue a request; returns a ticket usable with :meth:`result`.

        A result-cache hit short-circuits the queue entirely: the ticket is
        issued already-resolved (ready for :meth:`result` immediately), no
        lane is occupied, no tick runs.  ``_skip_cache`` lets the async
        layer opt out when it has already consulted the cache itself.

        ``_trace`` lets the async layer hand down the request's
        :class:`~repro.serve.tracing.RequestTrace` (already carrying its
        scheduler-side ``queued`` phase); without one, a traced engine opens
        a fresh trace at submission."""
        self._pool_key(req, 0)  # validate method early
        idx = self._next_idx
        self._next_idx += 1
        rt = _trace
        if rt is None and self.tracer is not None:
            rt = self.tracer.request(seed=req.seed, method=req.method)
        if rt is not None:
            self._rt[idx] = rt
        if not _skip_cache:
            hit = self.cached_result(req)
            if hit is not None:
                if rt is not None:
                    rt.event("cache_hit", seed=req.seed)
                self._complete(idx, hit)
                return idx
        self._enqueue(idx, req, 0)
        return idx

    def live_pools(self) -> List[Tuple[tuple, _Pool]]:
        """Snapshot of (key, pool) pairs that currently have work, in LRU
        order (least recently progressed/enqueued first).  The deadline
        scheduler plans over this; :meth:`poll` sweeps it."""
        return [(k, p) for k, p in list(self.pools.items()) if p.has_work()]

    def tick_pool(self, key: tuple) -> Optional[float]:
        """One refill → step → harvest pass of a *single* pool — the unit of
        work the deadline scheduler orders.  Returns the measured wall time
        in seconds (also folded into the pool's ``cost_ema``), or None if
        the pool is gone or idle.  A progressed pool is moved to the MRU end
        so LRU iteration (:meth:`poll`) stays fair."""
        pool = self.pools.get(key)
        if pool is None or not pool.has_work():
            return None
        tr = self.tracer
        if tr is None:
            t0 = time.perf_counter()
            pool.refill()
            pool.step()
            pool.harvest()  # device→host sync: the measured time is honest
            dt = time.perf_counter() - t0
        else:
            label = pool_label(key)
            with tr.span("tick", cat="pool", pool=label,
                         occupancy=pool.occupancy(), queued=len(pool.queue),
                         cost_ema=pool.cost_ema) as tick_sid, \
                    tr.scope(parent=tick_sid), \
                    tr.device_span(f"tick:{label}"):
                t0 = time.perf_counter()
                with tr.span("refill", cat="pool", parent=tick_sid):
                    pool.refill()
                with tr.span("step", cat="pool", parent=tick_sid):
                    pool.step()
                with tr.span("harvest", cat="pool", parent=tick_sid):
                    pool.harvest()
                dt = time.perf_counter() - t0
        pool.note_tick(dt)
        if key in self.pools:   # harvest may promote+evict this very pool
            self.pools.move_to_end(key)
        return dt

    def poll(self) -> bool:
        """One scheduler sweep: refill, step, and harvest every live pool,
        visiting pools in LRU order and moving each progressed pool to the
        MRU end.  A continuously-refilled hot pool therefore sinks behind
        colder pools between sweeps and can never starve their harvest under
        ``submit()``/``poll()`` interleaving.  Returns True if any pool made
        progress."""
        progressed = False
        for key in list(self.pools):  # LRU order: coldest pools first
            if self.tick_pool(key) is not None:
                progressed = True
        return progressed

    def pending(self) -> int:
        return sum(1 for p in self.pools.values() if p.has_work())

    def drain(self) -> None:
        """Run the scheduler until every submitted request has a result."""
        while self.poll():
            pass
        self._evict_idle()

    def _ticket_status(self, ticket) -> str:
        """"ready" | "pending" | "never-issued" | "consumed"."""
        if ticket in self._results:
            return "ready"
        if (not isinstance(ticket, (int, np.integer)) or ticket < 0
                or ticket >= self._next_idx):
            return "never-issued"
        for pool in self.pools.values():
            if ticket in pool.tickets():
                return "pending"
        return "consumed"

    def result(self, ticket: int) -> ClusterResult:
        """Pop the finished :class:`ClusterResult` for ``ticket``.  Raises
        :class:`UnknownTicket` (a ``KeyError``) with a diagnosis — never
        issued, already consumed, or still in flight — instead of a bare
        ``dict.pop`` KeyError."""
        status = self._ticket_status(ticket)
        if status == "ready":
            res = self._results.pop(ticket)
            self._finish_trace(ticket, res)
            return res
        if status == "pending":
            raise UnknownTicket(
                f"ticket {ticket} is still in flight — call poll()/drain() "
                f"until it completes, or peek() to test readiness")
        if status == "never-issued":
            raise UnknownTicket(
                f"ticket {ticket!r} was never issued by this engine")
        raise UnknownTicket(
            f"ticket {ticket} was already consumed "
            f"(result() returns each result exactly once)")

    def peek(self, ticket: int) -> Optional[ClusterResult]:
        """Non-consuming :meth:`result`: the finished result, or None while
        the ticket is still in flight.  Raises :class:`UnknownTicket` for
        never-issued or already-consumed tickets."""
        status = self._ticket_status(ticket)
        if status == "ready":
            return self._results[ticket]
        if status == "pending":
            return None
        raise UnknownTicket(
            f"ticket {ticket!r} was "
            + ("never issued by this engine" if status == "never-issued"
               else "already consumed"))

    def take_completed(self, tickets=None) -> Dict[int, ClusterResult]:
        """Pop finished results in bulk: {ticket: result} (exactly-once,
        like :meth:`result`).  ``tickets`` restricts the pickup to that set
        — the deadline scheduler passes the tickets it owns, so results
        submitted to a shared engine out-of-band stay claimable via
        :meth:`result`.  ``None`` pops everything."""
        if tickets is None:
            out, self._results = self._results, {}
        else:
            tickets = set(tickets)
            out = {t: r for t, r in self._results.items() if t in tickets}
            for t in out:
                del self._results[t]
        for t, r in out.items():
            self._finish_trace(t, r)
        return out

    def _finish_trace(self, ticket: int, res: ClusterResult) -> None:
        """Close a picked-up request's trace (the ``deliver`` phase ends at
        pickup, which is what the request's consumer actually waited for)."""
        rt = self._rt.pop(ticket, None)
        if rt is not None:
            rt.finish("expired" if res.deadline_missed else "resolved")

    def trace_for(self, ticket: int) -> Optional[RequestTrace]:
        """The in-flight :class:`~repro.serve.tracing.RequestTrace` for
        ``ticket`` (None once picked up, or for untraced requests)."""
        return self._rt.get(ticket)

    def harvest_partial(self, ticket: int) -> bool:
        """Force-finish a live request *now* for deadline expiry: a request
        resident in a lane is swept as-is (best-effort cluster from the
        partial diffusion); a still-queued request completes empty.  The
        result is recorded with ``deadline_missed=True`` and retrieved via
        :meth:`result`/:meth:`take_completed` like any other.  Returns False
        when the ticket isn't live (unknown, finished, or consumed)."""
        for key, pool in list(self.pools.items()):
            for i, slot in enumerate(pool.lane):
                if slot is not None and slot[0] == ticket:
                    res = pool.force_finalize(i)
                    res.deadline_missed = True
                    self.stats["partial_harvests"] += 1
                    self._complete(ticket, res)
                    return True
            for entry in pool.queue:
                if entry[0] == ticket:
                    pool.queue.remove(entry)
                    _, req = entry
                    rt = self._rt.get(ticket)
                    if rt is not None:
                        rt.event("expired", queued=True,
                                 pool=pool_label(key))
                    res = ClusterResult(
                        request=req, conductance=float("inf"), size=0,
                        volume=0, support=0,
                        cluster=np.zeros(0, np.int32), pushes=0,
                        iterations=0, bucket=pool.bucket, overflow=False,
                        backend=pool.backend, ops_backend=pool.ops_backend,
                        deadline_missed=True)
                    self.stats["partial_harvests"] += 1
                    self._complete(ticket, res)
                    return True
        return False

    def run(self, requests: List[ClusterRequest]) -> List[ClusterResult]:
        """Submit, drain, and return results in request order."""
        tickets = [self.submit(r) for r in requests]
        self.drain()
        return [self.result(t) for t in tickets]
