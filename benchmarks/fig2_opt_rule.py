"""Figure 2 reproduction: original vs optimized PR-Nibble update rule.

Paper claim (C2): the optimized (coordinate-descent step size) rule gives
the same conductance with 1.4–6.4× less work.  We report push counts (the
machine-independent work measure) and wall time, plus the sweep conductance
of both solutions.
"""
import numpy as np

from repro.core import pr_nibble, sweep_cut_dense
from .common import GRAPH_SUITE, get_graph, emit, timeit


def run(alpha=0.01, eps=1e-7, smoke: bool = False):
    graphs = ["sbm-planted"] if smoke else list(GRAPH_SUITE)
    if smoke:
        eps = 1e-6
    for name in graphs:
        g = get_graph(name)
        seed = 5 if name == "sbm-planted" else int(np.argmax(np.asarray(g.deg)))
        us_o, orig = timeit(pr_nibble, g, seed, eps, alpha, False, repeats=1)
        us_n, opt = timeit(pr_nibble, g, seed, eps, alpha, True, repeats=1)
        so = sweep_cut_dense(g, orig.p, 1 << 12, 1 << 18)
        sn = sweep_cut_dense(g, opt.p, 1 << 12, 1 << 18)
        speedup = int(orig.pushes) / max(int(opt.pushes), 1)
        emit(f"fig2/{name}/original", us_o,
             f"pushes={int(orig.pushes)};cond={float(so.best_conductance):.4f}")
        emit(f"fig2/{name}/optimized", us_n,
             f"pushes={int(opt.pushes)};cond={float(sn.best_conductance):.4f};"
             f"work_ratio={speedup:.2f}")


if __name__ == "__main__":
    run()
