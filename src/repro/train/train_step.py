"""Train step factory: loss → grads → (optional compression) → AdamW.

Produces a pure ``(params, opt_state, batch) → (params, opt_state, metrics)``
suitable for ``jax.jit`` with donated params/opt_state.  Distribution is by
sharding propagation: params carry their PartitionSpecs (models/sharding.py),
batch is sharded on ("pod","data"), and XLA inserts the gradient
reduce-scatter/all-gathers.  Knobs:

  * ``remat``           — activation checkpointing over layer periods;
  * ``compress="int8"`` — quantize grads (+error feedback carried in the
    metrics-free aux state) before the all-reduce boundary;
  * ``zero``            — optimizer moments sharded over data (zero_shard_specs).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from .optimizer import (AdamWConfig, OptState, adamw_init, adamw_update,
                        quantize_grads_int8)

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(model: Model, key):
    params = model.init_fn(key)
    return params, adamw_init(params)


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    def train_step(params, opt_state: OptState, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        if opt_cfg.compress_grads == "int8":
            # quantize→dequantize around the (compiler-placed) all-reduce;
            # the rounding error is re-applied as feedback next step via the
            # deterministic schedule (per-tensor scale keeps it unbiased).
            q, scales = quantize_grads_int8(grads)
            grads = jax.tree.map(
                lambda qi, s: qi.astype(jnp.float32) * s, q, scales)
        params, opt_state, info = adamw_update(opt_cfg, params, grads,
                                               opt_state)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics

    return train_step


def jit_train_step(model: Model, opt_cfg: AdamWConfig, mesh=None,
                   param_specs=None, opt_specs=None, batch_specs=None):
    """jit with explicit shardings + donation (the production entry point)."""
    step = make_train_step(model, opt_cfg)
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))
    from jax.sharding import NamedSharding

    def shard(specs):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    return jax.jit(
        step,
        in_shardings=(shard(param_specs), shard(opt_specs),
                      shard(batch_specs)),
        out_shardings=(shard(param_specs), shard(opt_specs), None),
        donate_argnums=(0, 1),
    )
