"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — under
``lax.scan``-stacked layers (and flash-attention inner scans) that
understates FLOPs/bytes by the trip count (verified: a 10-step scanned
matmul reports 1 matmul of FLOPs).  This walker parses the optimized HLO
text and:

  * multiplies every computation's cost by the enclosing ``while``
    ``backend_config known_trip_count`` (dynamic-trip loops use
    ``default_trip`` and are flagged);
  * counts dot FLOPs exactly: 2 · |result| · |contracted dims|;
  * counts HBM bytes at fusion boundaries (operands + result of top-level
    instructions — fusion internals do not touch HBM);
  * counts collective operand bytes (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), trip-multiplied.

Validated against cost_analysis on scan-free programs (tests/test_roofline).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "u1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops that move no HBM bytes themselves
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "while", "call", "conditional", "custom-call",
    "partition-id", "replica-id", "domain", "opt-barrier",
}
_ELTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "tanh",
    "exponential", "log", "rsqrt", "sqrt", "power", "negate", "compare",
    "select", "and", "or", "xor", "convert", "floor", "ceil", "abs",
}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    dynamic_loops: int = 0

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        self.collective_bytes += other.collective_bytes * mult
        for k in _COLLECTIVES:
            self.collective_breakdown[k] += other.collective_breakdown[k] * mult
        self.dynamic_loops += other.dynamic_loops


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems, total = 0, 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


class _Instr:
    __slots__ = ("name", "shape", "op", "line", "operands")

    def __init__(self, name, shape, op, line, operands):
        self.name = name
        self.shape = shape
        self.op = op
        self.line = line
        self.operands = operands


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]"
    r"(?:\{[^}]*\})?))\s+([\w\-]+)\(", )
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[="\{:\s]+n["\s:]+"?(\d+)')
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _parse_computations(text: str):
    comps: Dict[str, List[_Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    shapes: Dict[str, str] = {}
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape, op = m.group(1), m.group(2), m.group(3)
        paren = line[line.find("(", line.find(op)) + 1:]
        operands = re.findall(r"%([\w\.\-]+)", paren.split("),")[0])
        inst = _Instr(name, shape, op, line, operands)
        comps[cur].append(inst)
        shapes[name] = shape
    return comps, entry, shapes


def _dot_flops(inst: _Instr, shapes: Dict[str, str]) -> float:
    res_elems, _ = _shape_elems_bytes(inst.shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    if not m or not inst.operands:
        return 2.0 * res_elems  # degenerate
    lhs_shape = shapes.get(inst.operands[0], "")
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * res_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    for di in m.group(1).split(","):
        if di != "" and int(di) < len(dims):
            contracted *= dims[int(di)]
    return 2.0 * res_elems * contracted


_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")
_SLICING = ("dynamic-slice", "slice", "gather")


def _fusion_bytes(inst: "_Instr", sub_instrs, shapes, res_bytes) -> float:
    """Operand-utilization-aware fusion traffic.

    XLA fuses dynamic-slice/gather INTO consumers, so a fusion operand that
    is only sliced inside contributes slice-result bytes, not the whole
    buffer (the difference is the scan trip count — a 40× error on scanned
    layers).  Likewise a fused in-place dynamic-update-slice writes only the
    update region.
    """
    # map parameter index -> internal name, and collect internal uses
    pname_by_idx: Dict[int, str] = {}
    uses: Dict[str, List["_Instr"]] = {}
    has_dus = False
    dus_update_bytes = 0.0
    dus_param_names = set()
    for si in sub_instrs:
        if si.op == "parameter":
            m = _PARAM_NUM_RE.search(si.line)
            if m:
                pname_by_idx[int(m.group(1))] = si.name
        for o in si.operands:
            uses.setdefault(o, []).append(si)
        if si.op == "dynamic-update-slice":
            has_dus = True
            if len(si.operands) > 1 and si.operands[1] in shapes:
                dus_update_bytes += _shape_elems_bytes(shapes[si.operands[1]])[1]
            if si.operands and si.operands[0] in shapes:
                dus_param_names.add(si.operands[0])
    total = 0.0
    for i, oname in enumerate(inst.operands):
        full = _shape_elems_bytes(shapes[oname])[1] if oname in shapes else 0
        pname = pname_by_idx.get(i)
        puses = uses.get(pname, []) if pname else []
        if pname and pname in dus_param_names:
            continue  # in-place destination: write counted below
        if puses and all(u.op in _SLICING for u in puses):
            total += sum(_shape_elems_bytes(u.shape)[1] for u in puses)
        else:
            total += full
    if has_dus:
        total += 2 * dus_update_bytes        # read + write the update region
    else:
        total += res_bytes
    return total


def _comp_cost(comp: str, comps, shapes, cache: Dict[str, HloCost],
               default_trip: int) -> HloCost:
    if comp in cache:
        return cache[comp]
    cost = HloCost()
    cache[comp] = cost  # provisional (cycles shouldn't occur)
    for inst in comps.get(comp, []):
        op = inst.op
        if op == "while":
            body = _BODY_RE.search(inst.line)
            cond = _COND_RE.search(inst.line)
            trip_m = _TRIP_RE.search(inst.line)
            trip = int(trip_m.group(1)) if trip_m else default_trip
            if not trip_m:
                cost.dynamic_loops += 1
            if body:
                cost.add(_comp_cost(body.group(1), comps, shapes, cache,
                                    default_trip), trip)
            if cond:
                cost.add(_comp_cost(cond.group(1), comps, shapes, cache,
                                    default_trip), trip)
            continue
        if op in ("call", "async-start"):
            c = _CALLS_RE.search(inst.line)
            if c:
                cost.add(_comp_cost(c.group(1), comps, shapes, cache,
                                    default_trip))
            continue
        if op == "conditional":
            br = _BRANCHES_RE.search(inst.line)
            if br:
                subs = re.findall(r"%?([\w\.\-]+)", br.group(1))
                if subs:
                    sub_costs = [_comp_cost(s, comps, shapes, cache,
                                            default_trip) for s in subs]
                    worst = max(sub_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(worst)
            continue
        # ---- leaf-ish instructions ----
        res_elems, res_bytes = _shape_elems_bytes(inst.shape)
        opnd_bytes = 0
        for o in inst.operands:
            if o in shapes:
                opnd_bytes += _shape_elems_bytes(shapes[o])[1]
        if op == "fusion":
            c = _CALLS_RE.search(inst.line)
            sub_instrs = comps.get(c.group(1), []) if c else []
            if c:
                sub = _comp_cost(c.group(1), comps, shapes, cache,
                                 default_trip)
                # flops from inside the fusion; bytes at the boundary
                cost.flops += sub.flops
                cost.transcendentals += sub.transcendentals
            cost.bytes += _fusion_bytes(inst, sub_instrs, shapes, res_bytes)
            continue
        if op in ("dot", "convolution"):
            cost.flops += _dot_flops(inst, shapes)
            cost.bytes += res_bytes + opnd_bytes
            continue
        coll = None
        for ckind in _COLLECTIVES:
            if op == ckind or op == ckind + "-start":
                coll = ckind
                break
        if coll is not None:
            cost.collective_bytes += opnd_bytes
            cost.collective_breakdown[coll] += opnd_bytes
            cost.bytes += res_bytes + opnd_bytes
            continue
        if op.endswith("-done"):
            continue
        if op in _FREE_OPS:
            continue
        if op in _ELTWISE_FLOP_OPS:
            cost.flops += res_elems
            if op in ("tanh", "exponential", "log", "rsqrt", "sqrt", "power"):
                cost.transcendentals += res_elems
        # slicing ops touch only the slice, not the whole operand — counting
        # full operands would inflate scan xs/ys traffic by the trip count
        # (XLA cost analysis uses the same convention)
        if op in ("dynamic-slice", "slice", "gather"):
            cost.bytes += 2 * res_bytes
            continue
        if op == "dynamic-update-slice":
            upd = (_shape_elems_bytes(shapes[inst.operands[1]])[1]
                   if len(inst.operands) > 1 and inst.operands[1] in shapes
                   else res_bytes)
            cost.bytes += 3 * upd          # read update, read+write region
            continue
        if op == "scatter":
            upd = (_shape_elems_bytes(shapes[inst.operands[-1]])[1]
                   if inst.operands and inst.operands[-1] in shapes
                   else res_bytes)
            cost.bytes += 3 * upd
            continue
        # generic data movement (copy, broadcast, reshape, sort, reduce,
        # iota, rng, pad, concatenate, ...)
        cost.bytes += res_bytes + opnd_bytes
    cache[comp] = cost
    return cost


def analyze_hlo(text: str, default_trip: int = 1) -> HloCost:
    comps, entry, shapes = _parse_computations(text)
    if entry is None:
        # fall back: treat the largest computation as entry
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    cache: Dict[str, HloCost] = {}
    # fusion sub-computation bytes must NOT be double counted: compute costs
    # freshly; fusions only take .flops from their sub-computation.
    return _comp_cost(entry, comps, shapes, cache, default_trip)
