"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function computes the same mathematical object as its kernel with plain
jax.numpy — no tiling, no VMEM reasoning — and is what the per-kernel
shape/dtype sweep tests assert against (``tests/test_kernels.py``).
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["band_spmv_ref", "scatter_accum_ref", "block_scan_ref",
           "spmv_csr_ref", "scatter_add_ref", "segment_merge_ref"]


def band_spmv_ref(nbr: jnp.ndarray, weights: jnp.ndarray,
                  p: jnp.ndarray) -> jnp.ndarray:
    """y[v] = Σ_k weights[v,k] · p[nbr[v,k]]; sentinel ids carry weight 0."""
    n = p.shape[0]
    safe = jnp.clip(nbr, 0, n - 1)
    vals = p[safe] * (nbr < n) * (nbr >= 0)
    return jnp.sum(vals * weights, axis=1)


def scatter_accum_ref(local: jnp.ndarray, vals: jnp.ndarray,
                      tile: int = 128) -> jnp.ndarray:
    """out[t, c] = Σ_j vals[t, j] · [local[t, j] == c]."""
    T, C = local.shape
    out = jnp.zeros((T, tile), jnp.float32)
    ok = (local >= 0) & (local < tile)
    t_idx = jnp.repeat(jnp.arange(T), C)
    c_idx = jnp.where(ok, local, 0).reshape(-1)
    v = jnp.where(ok, vals, 0.0).reshape(-1)
    return out.at[t_idx, c_idx].add(v)


def block_scan_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.cumsum(x)


def scatter_add_ref(vec, idx, vals, valid):
    """Masked scatter-add oracle for :func:`repro.core.ops.scatter_add`,
    structure-free: a host-side numpy left fold over the updates in
    submission order — the exact combine order both backends must
    reproduce, computed without any scatter/sort machinery.  Test-only
    (eager numpy, not jit-able)."""
    import numpy as np
    out = np.asarray(vec).copy()
    idx = np.asarray(idx)
    vals = np.asarray(vals).astype(out.dtype)
    valid = np.asarray(valid)
    for j in range(idx.shape[0]):
        if valid[j] and 0 <= idx[j] < out.shape[0]:
            out[idx[j]] += vals[j]
    return out


def segment_merge_ref(ids, vals, n: int, cap: int):
    """Duplicate-summing merge oracle for
    :func:`repro.core.ops.segment_merge`: a dense scatter-accumulate over the
    full id range followed by a top-``cap`` extraction of the support —
    no sorting pipeline at all, so it shares no structure with either
    backend implementation."""
    dense = jnp.zeros((n + 1,), jnp.float32).at[
        jnp.clip(ids, 0, n)].add(jnp.where(ids < n, vals, 0.0))
    hit = jnp.zeros((n + 1,), bool).at[jnp.clip(ids, 0, n)].set(ids < n)
    present = hit[:n]
    count = jnp.sum(present).astype(jnp.int32)
    pos = jnp.cumsum(present) - 1
    out_ids = jnp.full((cap,), n, jnp.int32).at[
        jnp.where(present, pos, cap)].set(jnp.arange(n), mode="drop")
    out_vals = jnp.zeros((cap,), jnp.float32).at[
        jnp.where(present, pos, cap)].set(dense[:n], mode="drop")
    return out_ids, out_vals, count


def spmv_csr_ref(indptr, indices, deg, p, coef: float = 0.5):
    """Dense reference for the full diffusion matrix–vector product
    p' = coef·(A D⁻¹)p (+ the self term added by the caller)."""
    n = deg.shape[0]
    out = jnp.zeros_like(p)
    src = jnp.repeat(jnp.arange(n), deg, total_repeat_length=indices.shape[0])
    contrib = coef * p[src] / jnp.maximum(deg[src], 1)
    return out.at[indices].add(contrib)
