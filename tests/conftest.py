import numpy as np
import pytest

from repro.graphs import sbm, rand_local, grid3d


@pytest.fixture(scope="session")
def sbm_graph():
    """8 planted clusters of 100 vertices (ground truth for recovery tests)."""
    return sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)


@pytest.fixture(scope="session")
def local_graph():
    return rand_local(2000, degree=5, seed=3)


@pytest.fixture(scope="session")
def grid_graph():
    return grid3d(10)


def dense_from_dict(d, n):
    out = np.zeros(n, dtype=np.float64)
    for k, v in d.items():
        out[k] = v
    return out
