"""Architecture registry: the 10 assigned archs + graph-engine configs.

``get_config(arch_id)`` returns the exact published configuration;
``smoke_config(arch_id)`` returns a reduced same-family config for CPU
smoke tests (small widths/layers/experts, f32).  ``SHAPE_GRID`` enumerates
the 40 assigned (arch × shape) cells with their applicability (skips are
documented in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.config import ModelConfig, SHAPES

from .mamba2_2p7b import CONFIG as _mamba2
from .gemma3_27b import CONFIG as _gemma3
from .phi3_mini_3p8b import CONFIG as _phi3
from .yi_6b import CONFIG as _yi6
from .yi_9b import CONFIG as _yi9
from .whisper_medium import CONFIG as _whisper
from .recurrentgemma_2b import CONFIG as _rgemma
from .llama4_maverick_400b import CONFIG as _llama4
from .kimi_k2_1t import CONFIG as _kimi
from .phi3_vision_4p2b import CONFIG as _phi3v

ARCHS: Dict[str, ModelConfig] = {c.arch_id: c for c in [
    _mamba2, _gemma3, _phi3, _yi6, _yi9, _whisper, _rgemma, _llama4, _kimi,
    _phi3v]}

# long_500k runs only for sub-quadratic stacks (SSM / hybrid / mostly-local);
# whisper's decoder domain caps at its trained context — see DESIGN.md.
LONG_OK = {"mamba2-2.7b", "recurrentgemma-2b", "gemma3-27b"}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; options {sorted(ARCHS)}")
    return ARCHS[arch_id]


def cell_supported(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    """(runnable?, reason-if-skipped) for an (arch × shape) cell."""
    if shape_name == "long_500k" and arch_id not in LONG_OK:
        if arch_id == "whisper-medium":
            return False, "enc-dec: 512k outside decoder domain (max 448)"
        return False, "pure full-attention stack: 512k dense-KV decode excluded"
    return True, ""


SHAPE_GRID: List[Tuple[str, str]] = [
    (a, s) for a in ARCHS for s in SHAPES
]


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced same-family config: runs one train/serve step on CPU."""
    full = get_config(arch_id)
    period = len(full.layer_pattern)
    n_layers = max(period + 1, 3)           # exercises scan + remainder
    kv_ratio = max(full.n_heads // max(full.n_kv_heads, 1), 1)
    n_heads = 4
    n_kv = max(n_heads // min(kv_ratio, 4), 1)
    return dataclasses.replace(
        full,
        n_layers=n_layers,
        d_model=64, n_heads=n_heads, n_kv_heads=n_kv, head_dim=16,
        d_ff=0 if full.ff_kind == "none" else 128,
        vocab=512,
        window=32, q_chunk=16, kv_chunk=32,
        n_experts=8 if full.ff_kind == "moe" else 0,
        top_k=min(full.top_k, 2) if full.ff_kind == "moe" else 0,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
        n_enc_layers=2 if full.enc_dec else 0,
        enc_seq=24 if full.enc_dec else full.enc_seq,
        n_modality_tokens=8 if full.n_modality_tokens else 0,
        param_dtype="float32", compute_dtype="float32",
    )
