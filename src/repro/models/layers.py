"""Elementary layers: RMSNorm, dense projections, RoPE, SwiGLU.

Plain functions over param dicts (no framework dependency): ``*_init`` builds
params, ``*_apply`` consumes them.  All matmuls run in the config's compute
dtype with f32 accumulation where it matters (norms, softmax, loss).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dense_init", "dense", "rmsnorm_init", "rmsnorm",
           "rope", "swiglu_init", "swiglu", "embed_init"]


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, in_shape, out_shape, dtype="bfloat16", scale=None):
    """General dense: weight [*in_shape, *out_shape], fan-in init."""
    fan_in = 1
    for s in in_shape:
        fan_in *= s
    scale = scale if scale is not None else fan_in ** -0.5
    w = jax.random.normal(key, (*in_shape, *out_shape), jnp.float32) * scale
    return {"w": w.astype(_dtype(dtype))}


def dense(params, x, spec: str):
    """einsum-specified projection, e.g. spec='bsd,dhq->bshq'."""
    return jnp.einsum(spec, x, params["w"])


def rmsnorm_init(dim, dtype="float32"):
    return {"scale": jnp.zeros((dim,), _dtype(dtype))}


def rmsnorm(params, x, eps=1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding over the last dim of x[..., S, H, Dh]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu_init(key, d_model, d_ff, dtype="bfloat16"):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, (d_model,), (d_ff,), dtype),
        "wg": dense_init(k2, (d_model,), (d_ff,), dtype),
        "wo": dense_init(k3, (d_ff,), (d_model,), dtype),
    }


def swiglu(params, x):
    h = dense(params["wi"], x, "bsd,df->bsf")
    g = dense(params["wg"], x, "bsd,df->bsf")
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    return dense(params["wo"], h, "bsf,fd->bsd")


def embed_init(key, vocab, d_model, dtype="bfloat16"):
    w = jax.random.normal(key, (vocab, d_model), jnp.float32)
    return {"w": (w * (d_model ** -0.5)).astype(_dtype(dtype))}
