"""Banded ELL SpMV Pallas kernel — the saturated-diffusion hot loop on TPU.

When a diffusion's frontier saturates (tiny ε / NCP sweeps on well-connected
graphs), each round approaches the full product p' = M·p with
M = (A·D⁻¹ + I)/2 (paper §4.2 footnote 2).  On a CPU that is Ligra's EdgeMap
over all vertices; on a TPU the natural formulation is a *blocked ELL SpMV*:

  * rows are packed ELL: ``nbr[n, W]`` neighbor ids, sentinel-padded;
  * graphs with locality (randLocal / 3D-grid — the paper's synthetic
    families — or any graph after a locality reordering) are **banded**:
    neighbors of row block i fall within ``halo`` blocks of the diagonal;
  * grid = (row_block i, band offset δ ∈ [0, 2·halo]): step (i, δ) loads the
    single 128-wide ``p`` block (i + δ − halo) into VMEM and gathers neighbor
    values with a **one-hot MXU contraction** — the TPU replacement for
    irregular loads: instead of B·W random accesses, a (B·W × B) one-hot
    matmul on the systolic array.  The output block is revisited across δ
    (δ is the fastest grid dimension ⇒ legal sequential accumulation).

Rows whose neighbors escape the band go through the CSR fallback in ops.py
(hybrid split: ELL kernel for the band, XLA scatter for escapers).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["band_spmv", "ROW_BLOCK"]

ROW_BLOCK = 128


def _band_spmv_kernel(nbr_ref, w_ref, p_ref, out_ref, *, halo: int,
                      nblocks: int):
    i = pl.program_id(0)
    d = pl.program_id(1)
    B = out_ref.shape[0]
    W = nbr_ref.shape[1]

    tgt = i + d - halo                       # p block this step is assigned
    visit_ok = (tgt >= 0) & (tgt < nblocks)  # clipped duplicates are skipped
    start = jnp.clip(tgt, 0, nblocks - 1) * B

    nbr = nbr_ref[...]                       # int32[B, W] global neighbor ids
    wgt = w_ref[...]                         # f32 [B, W]
    pblk = p_ref[...]                        # f32 [B] — p[start : start+B]

    local = nbr - start
    ok = (local >= 0) & (local < B) & visit_ok
    local = jnp.clip(local, 0, B - 1)

    # one-hot gather on the MXU: (B·W, B) @ (B, 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (B * W, B), 1)
    onehot = (iota == local.reshape(B * W, 1)).astype(jnp.float32)
    onehot = onehot * ok.reshape(B * W, 1).astype(jnp.float32)
    gathered = jax.lax.dot_general(
        onehot, pblk.reshape(B, 1), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(B, W)
    partial = jnp.sum(gathered * wgt, axis=1)

    @pl.when(d == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(d != 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("halo", "interpret"))
def band_spmv(nbr: jnp.ndarray, weights: jnp.ndarray, p: jnp.ndarray,
              halo: int = 1, interpret: bool = False) -> jnp.ndarray:
    """y[v] = Σ_k weights[v,k] · p[nbr[v,k]] for banded ELL tables.

    Args:
      nbr:     int32[n_pad, W] ELL neighbor ids (n_pad multiple of 128);
               out-of-band / padding entries must carry weight 0.
      weights: f32[n_pad, W]   per-edge weights (e.g. 1/(2 d(src)))
      p:       f32[n_pad]
      halo:    band radius in 128-row blocks.
    """
    n_pad, W = nbr.shape
    assert n_pad % ROW_BLOCK == 0, "pad rows to a multiple of 128"
    nblocks = n_pad // ROW_BLOCK
    grid = (nblocks, 2 * halo + 1)

    def p_index(i, d):
        return (jnp.clip(i + d - halo, 0, nblocks - 1),)

    return pl.pallas_call(
        functools.partial(_band_spmv_kernel, halo=halo, nblocks=nblocks),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, W), lambda i, d: (i, 0)),
            pl.BlockSpec((ROW_BLOCK, W), lambda i, d: (i, 0)),
            pl.BlockSpec((ROW_BLOCK,), p_index),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK,), lambda i, d: (i,)),
        interpret=interpret,
    )(nbr, weights, p)
