"""Sweep cut (paper §4.1, Theorem 1): parallel == sequential, exactly."""
import numpy as np
import pytest
import jax.numpy as jnp

try:  # only the property test below needs hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import pr_nibble, sweep_cut, sweep_cut_dense, seq
from repro.graphs import sbm, rand_local


def _run_both(graph, p_dense):
    n = graph.n
    sw = sweep_cut_dense(graph, jnp.asarray(p_dense, jnp.float32),
                         cap_n=1 << 11, cap_e=1 << 16)
    assert not bool(sw.overflow)
    p_dict = {i: float(p_dense[i]) for i in np.flatnonzero(p_dense > 0)}
    ref = seq.seq_sweep_cut(graph, p_dict)
    return sw, ref


def test_sweep_matches_sequential_on_diffusion(sbm_graph):
    res = pr_nibble(sbm_graph, 5, eps=1e-6, alpha=0.05)
    sw, ref = _run_both(sbm_graph, np.asarray(res.p))
    assert int(sw.best_size) == ref["best_size"]
    assert float(sw.best_conductance) == pytest.approx(
        ref["best_conductance"], rel=1e-5)
    # identical member set
    assert sorted(np.asarray(sw.cluster())[: int(sw.best_size)].tolist()) == \
        sorted(ref["cluster"])


def test_sweep_finds_planted_cluster(sbm_graph):
    res = pr_nibble(sbm_graph, 5, eps=1e-7, alpha=0.01)
    sw = sweep_cut_dense(sbm_graph, res.p, 1 << 11, 1 << 17)
    # seed 5 lives in block 0 = vertices [0, 100)
    members = np.asarray(sw.cluster())[: int(sw.best_size)]
    frac_in_block = np.mean(members < 100)
    assert frac_in_block > 0.9
    assert float(sw.best_conductance) < 0.2


def test_sweep_conductance_definition(sbm_graph):
    """φ(S_j) from the prefix arrays equals direct recomputation."""
    res = pr_nibble(sbm_graph, 7, eps=1e-6, alpha=0.05)
    sw = sweep_cut_dense(sbm_graph, res.p, 1 << 11, 1 << 16)
    order = np.asarray(sw.order)
    for j in [1, 3, 10, int(sw.best_size)]:
        if j > int(sw.nnz):
            continue
        cond = seq.conductance_of_set(sbm_graph, order[:j])
        assert float(sw.conductance[j - 1]) == pytest.approx(cond, rel=1e-5)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_sweep_random_vectors_match_sequential(seed):
        """Property: for arbitrary sparse vectors on a fixed graph, the
        parallel sweep returns the sequential sweep's conductance."""
        rng = np.random.default_rng(seed)
        graph = rand_local(500, degree=4, seed=11)
        nnz = rng.integers(2, 60)
        ids = rng.choice(500, size=nnz, replace=False)
        p = np.zeros(500, dtype=np.float32)
        p[ids] = rng.random(nnz).astype(np.float32) + 1e-3
        sw, ref = _run_both(graph, p)
        assert float(sw.best_conductance) == pytest.approx(
            ref["best_conductance"], rel=1e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed "
                      "(pip install -r requirements-dev.txt)")
    def test_sweep_random_vectors_match_sequential():
        pass
