"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Loads (or initializes) a model, then serves a synthetic request stream
through the continuous-batching engine — the serving counterpart of
launch/train.py.  Use --smoke for the CPU-sized config.
"""
import argparse
import os
import time


def _early_args():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")


_early_args()

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.configs import get_config, smoke_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import latest_step, load_pytree  # noqa: E402
from repro.serve import ServeConfig, batched_serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--devices", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params = model.init_fn(jax.random.PRNGKey(0))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        restored, step = load_pytree({"params": params}, args.ckpt_dir)
        params = restored["params"]
        print(f"restored params from step {step}")

    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab,
                             size=rng.integers(4, args.prompt_len))
                for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = batched_serve(model, params, requests,
                         batch_slots=args.batch_slots,
                         cfg=ServeConfig(max_new_tokens=args.max_new),
                         prompt_len=args.prompt_len)
    dt = time.perf_counter() - t0
    toks = sum(len(o) for o in outs)
    print(f"{len(requests)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
