"""AdamW with global-norm clipping, warmup-cosine schedule, ZeRO-style
optimizer-state sharding and optional int8 gradient compression.

No optax in this environment — the optimizer is ~80 lines of pytree math,
which also makes the sharding story explicit:

  * baseline: optimizer moments share the parameter PartitionSpec;
  * ``zero=True``: moments are additionally sharded over the ``data`` axis on
    their largest divisible dimension (ZeRO-1) — the dry-run shows the
    memory delta;
  * ``compress_grads="int8"``: gradients are quantized per-tensor with error
    feedback before the (compiler-inserted) all-reduce — a distributed-
    optimization knob for straggler/bandwidth-limited pods.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "warmup_cosine", "clip_by_global_norm", "zero_shard_specs",
           "quantize_grads_int8"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    zero: bool = False
    compress_grads: Optional[str] = None   # None | "int8"


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params),
                    count=jnp.zeros((), jnp.int32))


def warmup_cosine(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def quantize_grads_int8(grads):
    """Per-tensor symmetric int8 quantization (error feedback is applied by
    the caller across steps).  Returns (q, scales) — the all-reduce then
    moves 4× fewer bytes; dequantize with q·scale."""
    def q(g):
        a = jnp.max(jnp.abs(g)) + 1e-12
        scale = a / 127.0
        return (jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8),
                scale)
    flat, tdef = jax.tree.flatten(grads)
    qs = [q(g) for g in flat]
    return (jax.tree.unflatten(tdef, [x[0] for x in qs]),
            jax.tree.unflatten(tdef, [x[1] for x in qs]))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state.count + 1
    lr = warmup_cosine(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_mu = jax.tree.map(lambda g, m: cfg.b1 * m + (1 - cfg.b1) * g,
                          grads, state.mu)
    new_nu = jax.tree.map(lambda g, v: cfg.b2 * v + (1 - cfg.b2) * jnp.square(g),
                          grads, state.nu)

    def upd(p, m, v):
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    return new_params, OptState(new_mu, new_nu, count), \
        {"grad_norm": gnorm, "lr": lr}


def zero_shard_specs(param_spec_tree, params_shape, mesh, axis: str = "data"):
    """ZeRO-1: shard each moment on its largest dim divisible by |axis|
    that the param spec leaves unsharded."""
    size = mesh.shape[axis]

    def one(spec, shp):
        if axis in tuple(spec):       # already sharded on this axis (FSDP)
            return spec
        dims = list(spec) + [None] * (len(shp.shape) - len(spec))
        best, best_d = -1, -1
        for d, (s, cur) in enumerate(zip(shp.shape, dims)):
            if cur is None and s % size == 0 and s > best:
                best, best_d = s, d
        if best_d < 0:
            return P(*dims)
        dims[best_d] = axis
        return P(*dims)

    return jax.tree.map(one, param_spec_tree, params_shape)
