"""Network Community Profile driver (paper §5, Figure 10).

NCP(s) = best conductance over all found clusters of size s.  The paper
generates it by running PR-Nibble from 10⁵ random seeds over a grid of
(α, ε) and sweeping each output — "a straightforward way to use parallelism
is to run many local graph computations independently in parallel".

Here that outer loop is *vmapped*: a whole batch of seeds runs as one XLA
program (each inner while_loop steps until every lane finishes), and batches
are sharded over the `data` mesh axis by the distributed launcher.  This is
the multi-pod embodiment of the paper's interactive-analytics workload.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.csr import CSRGraph
from .pr_nibble import pr_nibble_fixedcap
from .sweep import sweep_cut_dense

__all__ = ["NCPResult", "ncp_batch", "ncp"]


class NCPResult(NamedTuple):
    sizes: np.ndarray         # int — cluster size grid (1..max)
    best_conductance: np.ndarray  # f32 per size (inf where none found)
    num_runs: int


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6))
def ncp_batch(graph: CSRGraph, seeds: jnp.ndarray, params: jnp.ndarray,
              cap_f: int, cap_e: int, cap_n: int, sweep_cap_e: int):
    """One vmapped batch: seeds[i] with (eps, alpha) = params[i].

    Returns per-run (sizes[cap_n], conductances[cap_n], overflow) — the
    full sweep curve so every prefix feeds the NCP, not just the argmin.
    """
    def one(seed, par):
        eps, alpha = par[0], par[1]
        res = pr_nibble_fixedcap(graph, seed, eps, alpha, True, cap_f, cap_e)
        sw = sweep_cut_dense(graph, res.p, cap_n, sweep_cap_e)
        return sw.conductance, sw.nnz, res.overflow | sw.overflow

    return jax.vmap(one)(seeds, params)


def ncp(graph: CSRGraph, num_seeds: int = 256,
        alphas=(0.1, 0.01), epss=(1e-5, 1e-6, 1e-7),
        batch: int = 64, seed: int = 0,
        cap_f: int = 1 << 12, cap_e: int = 1 << 16,
        cap_n: int = 1 << 12, sweep_cap_e: int = 1 << 18) -> NCPResult:
    """Host driver: grid of (seed, α, ε) runs, batched + vmapped."""
    rng = np.random.default_rng(seed)
    deg = np.asarray(graph.deg)
    nonzero = np.flatnonzero(deg > 0)
    seeds = rng.choice(nonzero, size=num_seeds, replace=True).astype(np.int32)
    grid = [(e, a) for a in alphas for e in epss]

    cap_n = min(cap_n, graph.n)   # sweep clamps its prefix cap to n
    best = np.full((cap_n,), np.inf, dtype=np.float32)
    runs = 0
    for (eps, alpha) in grid:
        for lo in range(0, num_seeds, batch):
            sb = jnp.asarray(seeds[lo: lo + batch])
            if sb.shape[0] < batch:  # pad final batch
                sb = jnp.concatenate([sb, jnp.repeat(sb[:1], batch - sb.shape[0])])
            pars = jnp.tile(jnp.asarray([[eps, alpha]], jnp.float32), (batch, 1))
            conds, nnzs, ovf = ncp_batch(graph, sb, pars, cap_f, cap_e,
                                         cap_n, sweep_cap_e)
            conds = np.array(conds)           # writable copy off-device
            ok = ~np.asarray(ovf)
            conds[~ok] = np.inf
            best = np.minimum(best, conds.min(axis=0))
            runs += int(ok.sum())
    sizes = np.arange(1, cap_n + 1)
    return NCPResult(sizes=sizes, best_conductance=best, num_runs=runs)
