"""kimi-k2-1t-a32b — trillion-param MoE 384e top-8 (paper-table)
[arXiv:2501.kimi2; unverified].
61L d_model=7168 64H (kv=8) d_ff=2048/expert vocab=163840."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab=163840,
    layer_pattern=("attn",),
    ff_kind="moe", n_experts=384, top_k=8,
    source="arXiv:2501.kimi2 (unverified)",
)
