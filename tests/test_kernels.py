"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import band_spmv, scatter_accum_tiles, block_scan, BLOCK
from repro.kernels import ops, ref
from repro.graphs import rand_local, grid3d


# ---------------------------------------------------------------- band_spmv

@pytest.mark.parametrize("n_pad,W,halo", [
    (256, 3, 1), (512, 8, 1), (512, 5, 2), (1024, 16, 2), (128, 1, 0),
])
def test_band_spmv_shapes(n_pad, W, halo):
    rng = np.random.default_rng(n_pad + W + halo)
    nbr = np.full((n_pad, W), n_pad, np.int32)
    wgt = np.zeros((n_pad, W), np.float32)
    nblocks = n_pad // 128
    for v in range(n_pad):
        for k in range(W):
            if rng.random() < 0.7:
                blk = v // 128
                lo = max(0, (blk - halo)) * 128
                hi = min(nblocks, blk + halo + 1) * 128
                nbr[v, k] = rng.integers(lo, hi)
                wgt[v, k] = rng.random()
    p = rng.random(n_pad).astype(np.float32)
    y = band_spmv(jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(p),
                  halo=halo, interpret=True)
    exp = ref.band_spmv_ref(jnp.asarray(nbr), jnp.asarray(wgt), jnp.asarray(p))
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


def test_hybrid_diffusion_spmv_matches_csr(local_graph):
    """ELL band + COO escapers == full CSR diffusion product."""
    g = local_graph
    nbr, wgt, es, ed, ew, n_pad, W = ops.pack_banded_ell(g, halo=2, coef=0.5)
    rng = np.random.default_rng(0)
    p = jnp.asarray(rng.random(n_pad), jnp.float32)
    y = ops.diffusion_spmv(nbr, wgt, es, ed, ew, p, halo=2)
    gnp = g.to_numpy()
    src = np.repeat(np.arange(g.n), gnp.deg)
    exp = np.zeros(n_pad, np.float32)
    np.add.at(exp, src, 0.5 * np.asarray(p)[gnp.indices[: 2 * g.m]]
              / gnp.deg[gnp.indices[: 2 * g.m]])
    np.testing.assert_allclose(np.asarray(y), exp, rtol=1e-4, atol=1e-6)


# ------------------------------------------------------------ scatter_accum

@pytest.mark.parametrize("T,C", [(4, 64), (8, 256), (1, 16), (16, 128)])
def test_scatter_accum_tiles(T, C):
    rng = np.random.default_rng(T * 100 + C)
    local = rng.integers(-1, 128, size=(T, C)).astype(np.int32)
    vals = rng.random((T, C)).astype(np.float32)
    vals[local < 0] = 0.0
    out = scatter_accum_tiles(jnp.asarray(local), jnp.asarray(vals),
                              interpret=True)
    exp = ref.scatter_accum_ref(jnp.asarray(local), jnp.asarray(vals))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("n,m", [(100, 500), (1000, 5000), (257, 1)])
def test_scatter_add_via_mxu_equals_at_add(n, m):
    rng = np.random.default_rng(n + m)
    idx = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    vals = jnp.asarray(rng.random(m), jnp.float32)
    vec = jnp.asarray(rng.random(n), jnp.float32)
    out = ops.scatter_add_via_mxu(vec, idx, vals, chunk=64)
    exp = vec.at[idx].add(vals)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4,
                               atol=1e-5)


def test_scatter_overflow_spill_path():
    """More than `chunk` hits on one tile routes through the spill scatter."""
    n, m = 128, 600
    idx = jnp.zeros(m, jnp.int32)          # all collide on tile 0
    vals = jnp.ones(m, jnp.float32)
    out = ops.scatter_add_via_mxu(jnp.zeros(n, jnp.float32), idx, vals,
                                  chunk=256)
    assert float(out[0]) == pytest.approx(600.0)


# -------------------------------------------------------------- prefix scan

@pytest.mark.parametrize("n", [BLOCK, 3 * BLOCK, 7 * BLOCK])
def test_block_scan(n):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.random(n), jnp.float32)
    y = block_scan(x, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.cumsum(np.asarray(x)),
                               rtol=1e-4)


def test_prefix_sum_padding():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.random(5000), jnp.float32)
    y = ops.prefix_sum(x)
    np.testing.assert_allclose(np.asarray(y), np.cumsum(np.asarray(x)),
                               rtol=1e-4)
