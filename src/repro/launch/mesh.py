"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16×16 = 256 chips per pod with axes (data, model), and the
2-pod 512-chip variant with a leading "pod" axis.  The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; nothing else in the repo does (tests and benches see 1 device).
"""
from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=(8,), axes=("data",)):
    """Small host-device mesh for distributed tests (subprocess with
    --xla_force_host_platform_device_count=8)."""
    return make_mesh(shape, axes)
