"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within a chunk the recurrence is the quadratic "attention-like" form
(masked by the cumulative decay), across chunks the O(N)-state linear
recurrence is carried by a scan — O(S·Q) work, O(S/Q) sequential depth,
the layout that maps SSDs onto MXUs.

Decode is the pure recurrence: h ← exp(dt·A)·h + dt·(B ⊗ x), y = C·h + D·x
with state [B, H, P, N] carried in the serve cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init, dense, rmsnorm, rmsnorm_init

__all__ = ["mamba2_init", "mamba2_apply", "mamba2_decode_step",
           "mamba2_state_shape"]


def mamba2_init(key, cfg, dtype="bfloat16"):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    p = cfg.ssm_head_dim
    h = d_in // p
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z (gate), x, B, C, dt]
        "in_proj": dense_init(ks[0], (d,), (2 * d_in + 2 * n + h,), dtype),
        "out_proj": dense_init(ks[1], (d_in,), (d,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": rmsnorm_init(d_in),
    }


def mamba2_state_shape(cfg, batch):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return (batch, h, cfg.ssm_head_dim, cfg.ssm_state)


def _split_proj(cfg, zxbcdt):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    h = d_in // cfg.ssm_head_dim
    z, x, bb, cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1)
    return z, x, bb, cc, dt


def _segsum(a):
    """segsum(a)[..., i, j] = Σ_{k=j+1..i} a[..., k]  (−inf above diag)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_apply(params, u, cfg, return_state: bool = False):
    """u: [B, S, D] -> [B, S, D] via chunked SSD.

    With ``return_state`` also returns the post-sequence recurrent state
    [B, H, P, N] (what decode continues from)."""
    b, s, d = u.shape
    q = min(cfg.ssm_chunk, s)
    while s % q != 0:   # largest divisor ≤ ssm_chunk (shape-safe)
        q -= 1
    nchunks = s // q
    p = cfg.ssm_head_dim
    n = cfg.ssm_state
    d_in = cfg.ssm_expand * d
    h = d_in // p

    zxbcdt = dense(params["in_proj"], u, "bsd,de->bse")
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [b,s,h]
    a = -jnp.exp(params["A_log"])                                      # [h]
    x = x.reshape(b, s, h, p)
    bmat = bmat.astype(jnp.float32)                                    # [b,s,n]
    cmat = cmat.astype(jnp.float32)

    # chunked layout
    xc = x.reshape(b, nchunks, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nchunks, q, h)
    bc = bmat.reshape(b, nchunks, q, n)
    cc = cmat.reshape(b, nchunks, q, n)
    da = dtc * a[None, None, None, :]                                  # [b,c,q,h]

    # 1. intra-chunk (quadratic) term
    da_h = da.transpose(0, 1, 3, 2)                                    # [b,c,h,q]
    L = jnp.exp(_segsum(da_h))                                         # [b,c,h,q,q]
    # scores: C_i · B_j  → [b,c,q_i,q_j]
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    ydiag = jnp.einsum("bcij,bchij,bcjh,bcjhp->bcihp", cb, L, dtc, xc)

    # 2. per-chunk final states: S_c = Σ_j decay(end←j)·dt_j·B_j⊗x_j
    dec_end = jnp.exp(jnp.cumsum(da, axis=2)[:, :, -1:, :] -
                      jnp.cumsum(da, axis=2))                          # [b,c,q,h]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", dtc * dec_end, bc, xc)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(jnp.sum(da, axis=2))                         # [b,c,h]

    def chunk_step(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                                              # emit prev

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        chunk_step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)                 # [b,c,h,n,p]

    # 4. inter-chunk contribution: y_off = C_i · decay(i←start) · S_prev
    dec_in = jnp.exp(jnp.cumsum(da, axis=2))                           # [b,c,q,h]
    yoff = jnp.einsum("bcin,bcih,bchnp->bcihp", cc, dec_in, prev_states)

    y = (ydiag + yoff).reshape(b, s, h, p)
    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, d_in)
    # gated output norm (mamba2 uses RMSNorm(y * silu(z)))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(u.dtype))
    out = dense(params["out_proj"], y, "bse,ed->bsd")
    if return_state:
        # decode state layout is [B, H, P, N]
        return out, final_state.transpose(0, 1, 3, 2)
    return out


def mamba2_decode_step(params, u, state, cfg):
    """u: [B, 1, D]; state: [B, H, P, N] → (y [B,1,D], new state)."""
    b = u.shape[0]
    d = cfg.d_model
    p = cfg.ssm_head_dim
    zxbcdt = dense(params["in_proj"], u, "bsd,de->bse")
    z, x, bmat, cmat, dt = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])
    d_in = cfg.ssm_expand * d
    h = d_in // p
    x1 = x[:, 0].reshape(b, h, p).astype(jnp.float32)
    b1 = bmat[:, 0].astype(jnp.float32)                                # [b,n]
    c1 = cmat[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])                                   # [b,h]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, x1, b1)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c1)
    y = y + params["D"][None, :, None] * x1
    y = y.reshape(b, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(params["norm"], y.astype(u.dtype))
    return dense(params["out_proj"], y, "bse,ed->bsd"), state
