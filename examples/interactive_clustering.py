"""The paper's interactive-analytics workload (§1): an analyst explores a
graph by repeatedly (1) clustering around a seed, (2) inspecting the result,
(3) removing the cluster and continuing on the remainder — each query must
return "nearly instantaneously", which is exactly why single-query
parallelism matters.

This scripted session peels communities off an SBM graph one by one and also
shows the engine comparison the paper's §6 suggests (all four diffusions on
the same seed).

    PYTHONPATH=src python examples/interactive_clustering.py
"""
import time

import numpy as np
import jax

from repro.graphs import sbm, build_csr
from repro.core import (pr_nibble, nibble, hk_pr, rand_hk_pr, sweep_cut,
                        sweep_cut_dense)

graph = sbm(k=6, size=120, p_in=0.15, p_out=0.002, seed=3)
print(f"graph: n={graph.n} m={graph.m}\n")

# --- engine comparison on one seed (paper §6: no single engine dominates) --
seed = 10
for name, run in {
    "pr_nibble": lambda: pr_nibble(graph, seed, eps=1e-7, alpha=0.01).p,
    "nibble": lambda: nibble(graph, seed, eps=1e-8, T=20).p,
    "hk_pr": lambda: hk_pr(graph, seed, N=15, eps=1e-6, t=8.0).p,
}.items():
    t0 = time.perf_counter()
    sw = sweep_cut_dense(graph, run(), 1 << 11, 1 << 17)
    dt = time.perf_counter() - t0
    print(f"  {name:10s}: size={int(sw.best_size):4d} "
          f"φ={float(sw.best_conductance):.4f}  ({dt * 1e3:.0f} ms)")
r = rand_hk_pr(graph, seed, 8192, 12, 6.0, jax.random.PRNGKey(0))
sw = sweep_cut(graph, r.ids, r.vals, r.nnz, 1 << 17)
print(f"  {'rand_hk_pr':10s}: size={int(sw.best_size):4d} "
      f"φ={float(sw.best_conductance):.4f}\n")

# --- peel communities: cluster, remove, repeat -----------------------------
remaining = graph
id_map = np.arange(graph.n)          # remaining-local -> original ids
for round_i in range(4):
    deg = np.asarray(remaining.deg)
    seed_local = int(np.argmax(deg))  # analyst heuristic: a well-connected seed
    diff = pr_nibble(remaining, seed_local, eps=1e-7, alpha=0.01)
    sw = sweep_cut_dense(remaining, diff.p, 1 << 11, 1 << 17)
    members_local = np.asarray(sw.cluster())[: int(sw.best_size)]
    members = id_map[members_local]
    print(f"round {round_i}: peeled cluster of {len(members)} vertices "
          f"(φ={float(sw.best_conductance):.4f}); "
          f"communities touched: {sorted(set(members // 120))}")

    # remove the cluster and relabel the remainder
    keep = np.ones(remaining.n, bool)
    keep[members_local] = False
    new_ids = np.cumsum(keep) - 1
    g = remaining.to_numpy()
    src = np.repeat(np.arange(remaining.n), g.deg)
    dst = g.indices[: 2 * remaining.m]
    ok = keep[src] & keep[dst]
    remaining = build_csr(
        np.stack([new_ids[src[ok]], new_ids[dst[ok]]], 1), int(keep.sum()))
    id_map = id_map[keep]
    if remaining.m == 0:
        break
print(f"\nremaining graph: n={remaining.n} m={remaining.m}")
