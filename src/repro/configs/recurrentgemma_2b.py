"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; hf].
26L d_model=2560 10H (kv=1) d_ff=7680 vocab=256000, window 2048."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000,
    layer_pattern=("rglru", "rglru", "attn_local"),
    window=2048,
    source="arXiv:2402.19427 (hf)",
)
