import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.graphs import sbm, rand_local, grid3d


def run_subprocess_json(script: str, timeout: int = 900) -> dict:
    """Run a python script in a subprocess and parse its ``RESULT:<json>``
    line — the shared recipe for the 8-host-device distributed tests
    (the child sets its own ``XLA_FLAGS`` device count before importing
    jax, so the parent's flags are scrubbed to keep the recipe hermetic)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][0]
    return json.loads(line[len("RESULT:"):])


@pytest.fixture(scope="session")
def sbm_graph():
    """8 planted clusters of 100 vertices (ground truth for recovery tests)."""
    return sbm(k=8, size=100, p_in=0.15, p_out=0.002, seed=1)


@pytest.fixture(scope="session")
def local_graph():
    return rand_local(2000, degree=5, seed=3)


@pytest.fixture(scope="session")
def grid_graph():
    return grid3d(10)


def dense_from_dict(d, n):
    out = np.zeros(n, dtype=np.float64)
    for k, v in d.items():
        out[k] = v
    return out
