"""Kernel micro-benchmarks: Pallas hot-spot layers vs XLA baselines.

On CPU the Pallas kernels run in interpret mode (Python — wall time is
meaningless), so we benchmark the *wrapper pipelines* against their XLA
equivalents and report the work sizes the TPU kernels would see; the kernel
BlockSpec/VMEM reasoning lives in EXPERIMENTS.md §Roofline.
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops
from .common import get_graph, emit, timeit


def run(smoke: bool = False):
    g = get_graph("randLocal-50k")
    rng = np.random.default_rng(0)
    scan_n = 1 << 14 if smoke else 1 << 18

    # saturated diffusion step: hybrid ELL+COO vs pure XLA scatter
    nbr, wgt, es, ed, ew, n_pad, W = ops.pack_banded_ell(g, halo=2)
    p = jnp.asarray(rng.random(n_pad), jnp.float32)
    us, _ = timeit(ops.diffusion_spmv, nbr, wgt, es, ed, ew, p, halo=2)
    emit("kernels/band_spmv_hybrid", us,
         f"n={n_pad};W={W};escapers={int(es.shape[0])}")

    gnp = g.to_numpy()
    src = jnp.asarray(np.repeat(np.arange(g.n), gnp.deg), jnp.int32)
    dst = jnp.asarray(gnp.indices[: 2 * g.m], jnp.int32)
    w = jnp.asarray(0.5 / gnp.deg[gnp.indices[: 2 * g.m]], jnp.float32)

    def xla_scatter(p):
        return jnp.zeros(n_pad, jnp.float32).at[src].add(w * p[dst])

    us, _ = timeit(xla_scatter, p)
    emit("kernels/xla_scatter_baseline", us, f"edges={2 * g.m}")

    # prefix scan
    x = jnp.asarray(rng.random(scan_n), jnp.float32)
    us, _ = timeit(ops.prefix_sum, x)
    emit("kernels/prefix_sum_pallas_pipeline", us, f"n={scan_n}")
    us, _ = timeit(jnp.cumsum, x)
    emit("kernels/cumsum_xla_baseline", us, f"n={scan_n}")


if __name__ == "__main__":
    run()
