"""Pipeline parallelism (GPipe-style) over a mesh axis via shard_map.

The layer stack is split into `n_stages` contiguous groups; stage s (device
coordinate along the ``pipe`` axis) holds only its group's parameters
(leading layer axis sharded over ``pipe``).  Microbatches stream through:
at tick t, stage s processes microbatch (t − s) and hands its activation to
stage s+1 with a ``collective_permute`` — the bubble is the standard
(S − 1)/(M + S − 1) fraction.

This composes with the existing axes: run it over the ``pod`` axis of the
production mesh for inter-pod pipelining (activations cross the slow
inter-pod links once per microbatch instead of gradients once per step —
the standard reason to pipeline across pods), keeping `data`×`model`
parallelism inside each pod.

`pp_forward` is forward-only (serving / dry-run); training composes it with
jax.grad under the same shard_map (grads of collective_permute are the
reverse permute — handled by JAX automatically).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["pp_forward", "build_pp_forward"]


def _stage_fn(local_params, x_mb, n_stages: int, axis: str,
              block_fn: Callable):
    """Runs inside shard_map.  local_params: this stage's layer slab
    (leading dim = layers_per_stage); x_mb: [M, mb, ...] microbatches
    (replicated input; only stage 0 reads it).  Returns [M, mb, ...] outputs
    (valid on the last stage; other stages return zeros)."""
    stage = jax.lax.axis_index(axis)
    m = x_mb.shape[0]
    ticks = m + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def apply_stage(x):
        def body(h, layer_params):
            return block_fn(layer_params, h), None
        h, _ = jax.lax.scan(body, x, local_params)
        return h

    def tick(carry, t):
        recv_buf, outputs = carry
        # stage 0 ingests microbatch t; others use what arrived last tick
        mb_idx = jnp.clip(t, 0, m - 1)
        x_in = jnp.where(stage == 0,
                         jax.lax.dynamic_index_in_dim(x_mb, mb_idx, 0,
                                                      keepdims=False),
                         recv_buf)
        y = apply_stage(x_in)
        # pass forward: stage s → s+1 (last stage's send is dropped)
        sent = jax.lax.ppermute(y, axis, perm)
        # last stage emits microbatch (t − (S−1)) at tick t
        out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
        emit = (stage == n_stages - 1) & (t >= n_stages - 1)
        outputs = jax.lax.cond(
            emit,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, out_idx, 0),
            lambda o: o,
            outputs)
        return (sent, outputs), None

    outputs0 = jnp.zeros_like(x_mb)
    recv0 = jnp.zeros_like(x_mb[0])
    (_, outputs), _ = jax.lax.scan(tick, (recv0, outputs0),
                                   jnp.arange(ticks))
    # broadcast the last stage's result to all stages so the caller sees a
    # replicated output (one extra permute-ring; cheap relative to compute)
    outputs = jax.lax.psum(
        jnp.where(stage == n_stages - 1, outputs, 0.0), axis)
    return outputs


def build_pp_forward(mesh, axis: str, n_stages: int, block_fn: Callable):
    """Returns pp(params_stacked, x_microbatches) -> outputs.

    params_stacked: [n_layers, ...] pytree, n_layers % n_stages == 0 —
    sharded over `axis` on the leading dim.  x_microbatches: [M, mb, ...]
    replicated.  Output: [M, mb, ...] replicated.
    """
    fn = functools.partial(_stage_fn, n_stages=n_stages, axis=axis,
                           block_fn=block_fn)
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False)


def pp_forward(mesh, axis: str, params_stacked, x_microbatches,
               block_fn: Callable):
    n_stages = mesh.shape[axis]
    return build_pp_forward(mesh, axis, n_stages, block_fn)(
        params_stacked, x_microbatches)
