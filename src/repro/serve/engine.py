"""Serving engine: prefill + decode loop with batched requests.

``generate``  — greedy/temperature decode for a fixed batch.
``batched_serve`` — continuous-batching driver: a request queue is packed
into fixed batch slots; finished slots are refilled without restarting the
others (slot-wise cache reuse), the standard production pattern.

The decode step is the same jit'd ``model.decode_fn`` the dry run lowers for
the decode_* cells; cache shardings come from models/sharding.py.

Telemetry: both serving stacks (this one and the clustering scheduler,
serve/scheduler.py) report into the same
:class:`repro.serve.telemetry.MetricsRegistry` type — pass one to
``batched_serve(telemetry=...)`` to get per-wave latency histograms and
request counters alongside the cluster scheduler's metrics in a single
JSON export.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.model import Model
from .telemetry import MetricsRegistry

__all__ = ["ServeConfig", "generate", "batched_serve"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 = greedy
    eos_id: int = -1                  # -1 = never stop early


def _sample(logits, key, temperature):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(model: Model, params, prompts: jnp.ndarray,
             cfg: ServeConfig = ServeConfig(), extra_inputs=None,
             key=None) -> jnp.ndarray:
    """prompts: [B, S] int32 → generated tokens [B, max_new_tokens]."""
    key = jax.random.PRNGKey(0) if key is None else key
    batch = {"tokens": prompts}
    if extra_inputs:
        batch.update(extra_inputs)
    max_seq = prompts.shape[1] + cfg.max_new_tokens + 1
    prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, max_seq=max_seq))
    decode = jax.jit(model.decode_fn)
    cache, logits = prefill(params, batch)
    outs = []
    tok = _sample(logits, key, cfg.temperature)[:, None].astype(jnp.int32)
    for i in range(cfg.max_new_tokens):
        outs.append(tok)
        logits, cache = decode(params, tok, cache)
        key = jax.random.fold_in(key, i)
        tok = _sample(logits, key, cfg.temperature)[:, None].astype(jnp.int32)
    return jnp.concatenate(outs, axis=1)


def batched_serve(model: Model, params, requests: List[np.ndarray],
                  batch_slots: int, cfg: ServeConfig = ServeConfig(),
                  prompt_len: Optional[int] = None,
                  telemetry: Optional[MetricsRegistry] = None
                  ) -> List[np.ndarray]:
    """Continuous batching over a request list.

    Requests are left-padded/truncated to ``prompt_len`` and packed into
    ``batch_slots`` lanes; each wave prefills the fresh lanes and decodes all
    lanes together.  Returns one generated array per request, in order.
    ``telemetry`` (optional) records per-wave latency under
    ``serve/wave_latency`` and counts requests under ``serve/requests`` —
    the same registry type the clustering scheduler feeds.
    """
    prompt_len = prompt_len or max(len(r) for r in requests)
    results: List[Optional[np.ndarray]] = [None] * len(requests)
    nxt = 0
    while nxt < len(requests):
        take = min(batch_slots, len(requests) - nxt)
        lanes = []
        for i in range(take):
            r = np.asarray(requests[nxt + i], dtype=np.int32)[:prompt_len]
            pad = np.zeros(prompt_len - r.shape[0], dtype=np.int32)
            lanes.append(np.concatenate([pad, r]))
        while len(lanes) < batch_slots:          # pad the wave
            lanes.append(np.zeros(prompt_len, dtype=np.int32))
        prompts = jnp.asarray(np.stack(lanes))
        t0 = time.perf_counter()
        gen = np.asarray(generate(model, params, prompts, cfg))
        if telemetry is not None:
            telemetry.observe("serve/wave_latency", time.perf_counter() - t0)
            telemetry.inc("serve/requests", take)
        for i in range(take):
            results[nxt + i] = gen[i]
        nxt += take
    return results  # type: ignore[return-value]
